//! Matrix test: every workload × every applicable map × several sizes
//! must produce identical results (the fundamental guarantee the whole
//! system rests on: the map changes *where blocks come from*, never
//! *what is computed*). Pure-Rust backend — runs without artifacts.
//!
//! The *full differential matrix* at the bottom sweeps every registered
//! [`WorkloadKind`] × every compatible map × both [`ExecMode`]s and
//! asserts identical outputs AND identical thread-population stats —
//! the class of bug the PR 3 ktuple m=2 `block_chunks` fix patched
//! ad-hoc (right answer, wrong launch geometry) can no longer land
//! silently.

use simplexmap::coordinator::{Backend, ExecMode, Job, Scheduler, WorkloadKind};
use simplexmap::maps::DomainKind;

fn run(sched: &Scheduler, w: WorkloadKind, nb: u64, map: &str) -> Vec<(String, f64)> {
    sched
        .run(&Job {
            workload: w,
            nb,
            map: map.into(),
            backend: Backend::Parallel,
            seed: 99,
        })
        .unwrap_or_else(|e| panic!("{} nb={nb} map={map}: {e}", w.name()))
        .outputs
}

fn assert_outputs_agree(
    name: &str,
    nb: u64,
    base: &[(String, f64)],
    got: &[(String, f64)],
    map: &str,
) {
    assert_eq!(base.len(), got.len());
    for ((k0, v0), (k1, v1)) in base.iter().zip(got) {
        assert_eq!(k0, k1);
        let tol = 1e-6 * v0.abs().max(1.0);
        assert!(
            (v0 - v1).abs() <= tol,
            "{name} nb={nb} map={map}: {k0} {v1} vs baseline {v0}"
        );
    }
}

#[test]
fn m2_workloads_agree_across_all_maps_and_sizes() {
    let sched = Scheduler::new(4, None);
    // Maps valid for general 2-simplex workloads at power-of-two sizes
    // (avril covers strict pairs only → excluded; see maps::avril).
    let maps = ["bb", "lambda2", "enum2", "rb", "ries", "above2", "below2", "lambda-s"];
    for w in [
        WorkloadKind::Edm,
        WorkloadKind::Collision,
        WorkloadKind::NBody,
        WorkloadKind::Cellular,
        WorkloadKind::TriMatVec,
    ] {
        for nb in [4u64, 8, 16] {
            let base = run(&sched, w, nb, maps[0]);
            for map in &maps[1..] {
                let got = run(&sched, w, nb, map);
                assert_outputs_agree(w.name(), nb, &base, &got, map);
            }
        }
    }
}

#[test]
fn m2_workloads_agree_at_non_power_of_two_sizes() {
    // The §III.A approaches must agree with BB at awkward sizes.
    let sched = Scheduler::new(4, None);
    for w in [WorkloadKind::Edm, WorkloadKind::Collision] {
        for nb in [6u64, 10, 12] {
            let base = run(&sched, w, nb, "bb");
            for map in ["above2", "below2", "rb", "enum2", "lambda-s"] {
                let got = run(&sched, w, nb, map);
                assert_outputs_agree(w.name(), nb, &base, &got, map);
            }
        }
    }
}

#[test]
fn m3_workloads_agree_across_maps_and_sizes() {
    let sched = Scheduler::new(4, None);
    let maps = ["bb", "lambda3", "enum3", "lambda3-rec", "lambda-s", "lambda-sw"];
    for nb in [4u64, 8] {
        let base = run(&sched, WorkloadKind::Triple, nb, maps[0]);
        for map in &maps[1..] {
            let got = run(&sched, WorkloadKind::Triple, nb, map);
            assert_outputs_agree("triple", nb, &base, &got, map);
        }
    }
}

/// Every map a workload can run under — the compatibility axis of the
/// differential matrix. Simplex workloads take every registered map of
/// their dimension except avril (strict pairs only, see maps::avril);
/// the gasket workload additionally runs under the m = 2 simplex maps
/// (the gasket embeds in the inclusive triangle).
fn compatible_maps(w: WorkloadKind) -> Vec<&'static str> {
    match w.domain() {
        DomainKind::Gasket => vec![
            "bb-gasket",
            "lambda-gasket",
            "bb",
            "lambda2",
            "enum2",
            "rb",
            "ries",
            "above2",
            "below2",
            "lambda-s",
        ],
        DomainKind::Simplex => match w.m() {
            2 => vec!["bb", "lambda2", "enum2", "rb", "ries", "above2", "below2", "lambda-s"],
            3 => vec!["bb", "lambda3", "enum3", "lambda3-rec", "lambda-s", "lambda-sw"],
            _ => vec!["bb", "lambda-m"],
        },
    }
}

/// Power-of-two sizes every compatible map accepts, scaled down as the
/// dimension (and thus the brute-force cost) grows.
fn matrix_sizes(w: WorkloadKind) -> &'static [u64] {
    match w.m() {
        2 => &[4, 8],
        3 => &[4],
        4 => &[4],
        _ => &[3],
    }
}

#[test]
fn full_matrix_outputs_agree_across_every_compatible_map() {
    // Axis 1 of the differential matrix: for each (workload, size),
    // every compatible map yields the same outputs as the first.
    let sched = Scheduler::new(4, None);
    for &w in WorkloadKind::ALL {
        let maps = compatible_maps(w);
        for &nb in matrix_sizes(w) {
            let base = run(&sched, w, nb, maps[0]);
            for map in &maps[1..] {
                let got = run(&sched, w, nb, map);
                assert_outputs_agree(w.name(), nb, &base, &got, map);
            }
        }
    }
}

#[test]
fn full_matrix_streaming_equals_collect_with_identical_stats() {
    // Axis 2 (widened in PR 6): for each (workload, map, size), every
    // execution-mode × backend combination — Streaming/Collect crossed
    // with Serial/Parallel — reports the same outputs AND all eight
    // accounting fields. Output agreement alone would miss a
    // map/geometry mismatch that predicates the error away; checking
    // only five fields let the old lane-starved pool miscount waves
    // unnoticed.
    let mut engines = Vec::new();
    for backend in [Backend::Serial, Backend::Parallel] {
        for mode in [ExecMode::Streaming, ExecMode::Collect] {
            let mut sched = Scheduler::new(3, None);
            sched.exec_mode = mode;
            engines.push((backend, mode, sched));
        }
    }
    for &w in WorkloadKind::ALL {
        for &nb in matrix_sizes(w) {
            for map in compatible_maps(w) {
                let label = format!("{} nb={nb} map={map}", w.name());
                let results: Vec<_> = engines
                    .iter()
                    .map(|(backend, mode, sched)| {
                        let j = Job {
                            workload: w,
                            nb,
                            map: map.into(),
                            backend: *backend,
                            seed: 99,
                        };
                        let r = sched
                            .run(&j)
                            .unwrap_or_else(|e| panic!("{label} {backend:?}/{mode:?}: {e}"));
                        (*backend, *mode, r)
                    })
                    .collect();
                let (_, _, base) = &results[0];
                for (backend, mode, r) in &results[1..] {
                    assert_eq!(
                        base.accounting(),
                        r.accounting(),
                        "{label}: accounting mismatch under {backend:?}/{mode:?}"
                    );
                    assert_outputs_agree(w.name(), nb, &base.outputs, &r.outputs, map);
                }
            }
        }
    }
}

#[test]
fn gasket_maps_and_simplex_covers_agree_exactly() {
    // The gasket CA is pure integer arithmetic, so *exact* equality is
    // required across its whole map row — including the simplex covers
    // that pay predication for the non-gasket triangle blocks.
    let sched = Scheduler::new(4, None);
    for nb in [4u64, 8, 16] {
        let base = run(&sched, WorkloadKind::GasketCA, nb, "lambda-gasket");
        for map in compatible_maps(WorkloadKind::GasketCA) {
            let got = run(&sched, WorkloadKind::GasketCA, nb, map);
            assert_eq!(base, got, "nb={nb} map={map}");
        }
    }
}

#[test]
fn results_depend_on_seed_not_map() {
    let sched = Scheduler::new(2, None);
    let a = run(&sched, WorkloadKind::Edm, 8, "lambda2");
    let sched2 = Scheduler::new(2, None);
    let b = sched2
        .run(&Job {
            workload: WorkloadKind::Edm,
            nb: 8,
            map: "lambda2".into(),
            backend: Backend::Parallel,
            seed: 100, // different seed → different data
        })
        .unwrap()
        .outputs;
    assert_ne!(a[1].1, b[1].1, "different seeds must differ");
}

#[test]
fn tiny_sizes_do_not_break() {
    let sched = Scheduler::new(1, None);
    // nb=2 is the smallest size every pow2 map accepts (λ3 needs 4).
    for map in ["bb", "lambda2", "rb", "enum2", "below2", "lambda-s"] {
        let out = run(&sched, WorkloadKind::Edm, 2, map);
        assert_eq!(out[0].0, "neighbour_count");
    }
    let out = run(&sched, WorkloadKind::Triple, 4, "lambda3");
    assert_eq!(out[0].0, "at_energy");
    // λ_S is the only λ-family map alive at nb=1 (both dimensions).
    for (w, map) in [
        (WorkloadKind::Edm, "lambda-s"),
        (WorkloadKind::Triple, "lambda-s"),
    ] {
        let out = run(&sched, w, 1, map);
        assert!(!out.is_empty(), "{} nb=1", w.name());
    }
}

#[test]
fn lambda_s_agrees_with_bb_at_odd_sizes_in_both_dimensions() {
    // The λ_S scalability row of the matrix: identical outputs at odd
    // and prime sizes, where the rest of the λ family cannot run.
    let sched = Scheduler::new(4, None);
    for nb in [5u64, 7, 9, 13] {
        for w in [WorkloadKind::Edm, WorkloadKind::Collision, WorkloadKind::KTuple(2)] {
            let base = run(&sched, w, nb, "bb");
            let got = run(&sched, w, nb, "lambda-s");
            assert_outputs_agree(w.name(), nb, &base, &got, "lambda-s");
        }
        let base = run(&sched, WorkloadKind::Triple, nb, "bb");
        let got = run(&sched, WorkloadKind::Triple, nb, "lambda-s");
        assert_outputs_agree("triple", nb, &base, &got, "lambda-s");
    }
}
