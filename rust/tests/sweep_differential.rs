//! Differential serving tests: a sweep served over the wire by the
//! reactor must produce byte-identical per-job result documents to the
//! same jobs run directly on a local scheduler — for every compatible
//! map of each workload family — and cursor pagination must reassemble
//! out-of-order worker completions into row-major submission order.
//! Only the nondeterministic timing fields (`wall_secs`, lane profile)
//! are stripped before comparison.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;

use simplexmap::coordinator::{Backend, Job, Reactor, ReactorConfig, Scheduler, WorkloadKind};
use simplexmap::util::json::{self, Json};

const SEED: u64 = 7;

fn start() -> (SocketAddr, std::thread::JoinHandle<()>) {
    let sched = Arc::new(Scheduler::new(2, None));
    let reactor = Reactor::with_config(sched, ReactorConfig::default());
    let (tx, rx) = std::sync::mpsc::channel();
    let handle = std::thread::spawn(move || {
        reactor
            .serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap())
            .unwrap();
    });
    (rx.recv().unwrap(), handle)
}

fn connect(addr: SocketAddr) -> (TcpStream, BufReader<TcpStream>) {
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_nodelay(true).unwrap();
    let reader = BufReader::new(stream.try_clone().unwrap());
    (stream, reader)
}

fn send(w: &mut TcpStream, line: &str) {
    w.write_all(line.as_bytes()).unwrap();
    w.write_all(b"\n").unwrap();
    w.flush().unwrap();
}

fn recv(r: &mut BufReader<TcpStream>) -> Json {
    let mut line = String::new();
    let n = r.read_line(&mut line).unwrap();
    assert!(n > 0, "server closed the connection unexpectedly");
    json::parse(line.trim()).unwrap_or_else(|e| panic!("bad frame {line:?}: {e}"))
}

fn shutdown(addr: SocketAddr, handle: std::thread::JoinHandle<()>) {
    let (mut w, mut r) = connect(addr);
    send(&mut w, r#"{"cmd":"shutdown"}"#);
    recv(&mut r);
    drop((w, r));
    handle.join().expect("reactor thread exits after shutdown");
}

/// Canonical byte form of a job-result document: everything except the
/// fields that legitimately differ between two executions of the same
/// job (wall-clock and the parallel backend's lane timing profile).
fn canonical(result: &Json) -> String {
    let mut doc = result.clone();
    if let Json::Obj(m) = &mut doc {
        m.remove("wall_secs");
        m.remove("lane_imbalance");
        m.remove("lane_profile");
    }
    doc.to_string_compact()
}

/// The same job the sweep expansion builds for (workload, nb, map),
/// executed directly on a local scheduler.
fn local(sched: &Scheduler, workload: &str, nb: u64, map: &str) -> String {
    let job = Job {
        workload: WorkloadKind::parse(workload).expect("roster workload"),
        nb,
        map: map.to_string(),
        backend: Backend::Serial,
        seed: SEED,
    };
    let result = sched.run(&job).expect("local run succeeds");
    canonical(&result.to_json())
}

/// One row per workload family: (workload, nb, every compatible map).
/// Mirrors `compatible_maps` in workload_matrix.rs, including the
/// searched-width lambda-sw container for m = 3.
fn roster() -> Vec<(&'static str, u64, Vec<&'static str>)> {
    let m2 = || vec!["bb", "lambda2", "enum2", "rb", "ries", "above2", "below2", "lambda-s"];
    let m3 = || vec!["bb", "lambda3", "enum3", "lambda3-rec", "lambda-s", "lambda-sw"];
    let gasket = vec![
        "bb-gasket",
        "lambda-gasket",
        "bb",
        "lambda2",
        "enum2",
        "rb",
        "ries",
        "above2",
        "below2",
        "lambda-s",
    ];
    vec![
        ("edm", 8, m2()),
        ("collision", 8, m2()),
        ("nbody", 8, m2()),
        ("cellular", 8, m2()),
        ("trimatvec", 8, m2()),
        ("triple", 4, m3()),
        ("gasket", 4, gasket),
        ("ktuple4", 4, vec!["bb", "lambda-m"]),
    ]
}

fn sweep_request(workload: &str, nb: u64, maps: &[&str]) -> String {
    let quoted: Vec<String> = maps.iter().map(|m| format!("\"{m}\"")).collect();
    let maps_json = quoted.join(",");
    let mut req = format!(r#"{{"cmd":"sweep","workloads":["{workload}"],"nbs":[{nb}],"#);
    req.push_str(&format!(r#""maps":[{maps_json}],"backend":"serial","seed":{SEED}}}"#));
    req
}

#[test]
fn wire_sweep_results_match_local_runs_byte_for_byte() {
    let (addr, handle) = start();
    let local_sched = Scheduler::new(2, None);
    for (workload, nb, maps) in roster() {
        // Fresh connection per family: keeps every sweep independent
        // and stays clear of the per-connection active-sweep cap.
        let (mut w, mut r) = connect(addr);
        send(&mut w, &sweep_request(workload, nb, &maps));
        let ack = recv(&mut r);
        assert_eq!(
            ack.get("jobs").and_then(Json::as_u64),
            Some(maps.len() as u64),
            "{workload}: {ack:?}"
        );
        let mut wire: Vec<Option<String>> = vec![None; maps.len()];
        loop {
            let frame = recv(&mut r);
            if frame.get("done").and_then(Json::as_bool) == Some(true) {
                let failed = frame.get("failed").and_then(Json::as_u64);
                assert_eq!(failed, Some(0), "{workload}: {frame:?}");
                break;
            }
            assert_eq!(frame.get("ok").and_then(Json::as_bool), Some(true), "{frame:?}");
            let idx = frame.get("job").and_then(Json::as_u64).unwrap() as usize;
            let result = frame.get("result").expect("ok frame carries a result");
            assert!(wire[idx].is_none(), "{workload}: row {idx} streamed twice");
            wire[idx] = Some(canonical(result));
        }
        for (i, map) in maps.iter().enumerate() {
            let got = wire[i].as_ref().unwrap_or_else(|| panic!("{workload}/{map}: lost row"));
            let want = local(&local_sched, workload, nb, map);
            assert_eq!(got, &want, "{workload} nb={nb} {map}: wire and local results differ");
        }
        drop((w, r));
    }
    shutdown(addr, handle);
}

#[test]
fn reconnecting_client_recovers_identical_results_by_token() {
    let (addr, handle) = start();
    let local_sched = Scheduler::new(2, None);

    // Start a non-streaming sweep and hard-drop the connection right
    // after the ack — mid-flight for the fan-out, which must detach
    // and keep landing rows in the durable store.
    let nbs: [u64; 8] = [11, 4, 9, 5, 10, 6, 8, 7];
    let (sid, token) = {
        let (mut w, mut r) = connect(addr);
        let mut req = String::from(r#"{"cmd":"sweep","workloads":["edm"],"maps":["bb"],"#);
        req.push_str(&format!(
            r#""nbs":[11,4,9,5,10,6,8,7],"backend":"serial","seed":{SEED},"#
        ));
        req.push_str(r#""stream":false,"window":2}"#);
        send(&mut w, &req);
        let ack = recv(&mut r);
        assert_eq!(ack.get("ok").and_then(Json::as_bool), Some(true), "{ack:?}");
        assert_eq!(ack.get("jobs").and_then(Json::as_u64), Some(8), "{ack:?}");
        let sid = ack.get("sweep").and_then(Json::as_u64).unwrap();
        let token = ack
            .get("token")
            .and_then(Json::as_str)
            .expect("ack carries the durable token")
            .to_string();
        (sid, token)
        // w/r drop here — the TCP connection dies with rows in flight.
    };

    // Reconnect. The bare sweep id is another connection's property and
    // must be refused; the token is the cross-connection capability.
    let (mut w, mut r) = connect(addr);
    send(&mut w, &format!(r#"{{"cmd":"results","sweep":{sid},"cursor":0,"limit":3}}"#));
    let refused = recv(&mut r);
    assert_eq!(refused.get("ok").and_then(Json::as_bool), Some(false), "{refused:?}");
    assert!(
        refused.get("error").and_then(Json::as_str).unwrap().contains("unknown sweep"),
        "{refused:?}"
    );

    // Resume pagination by token until every row has landed.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
    let rows = loop {
        assert!(std::time::Instant::now() < deadline, "detached sweep never completed");
        let mut rows: Vec<Json> = Vec::new();
        let mut cursor = 0u64;
        let done = loop {
            let get = format!(
                r#"{{"cmd":"results","token":"{token}","cursor":{cursor},"limit":3}}"#
            );
            send(&mut w, &get);
            let page = recv(&mut r);
            assert_eq!(page.get("ok").and_then(Json::as_bool), Some(true), "{page:?}");
            assert_eq!(page.get("token").and_then(Json::as_str), Some(token.as_str()));
            let chunk = page.get("results").and_then(Json::as_arr).unwrap();
            rows.extend(chunk.iter().cloned());
            match page.get("next_cursor").and_then(Json::as_u64) {
                Some(next) => cursor = next,
                None => break page.get("done").and_then(Json::as_bool) == Some(true),
            }
        };
        if done && rows.iter().all(|row| !matches!(row, Json::Null)) {
            break rows;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    };

    // Byte-identical to the same jobs run on a local scheduler — the
    // disconnect must not change a single result byte.
    assert_eq!(rows.len(), nbs.len());
    for (i, row) in rows.iter().enumerate() {
        assert_eq!(row.get("job").and_then(Json::as_u64), Some(i as u64), "row order");
        assert_eq!(row.get("ok").and_then(Json::as_bool), Some(true), "{row:?}");
        let result = row.get("result").unwrap();
        let want = local(&local_sched, "edm", nbs[i], "bb");
        assert_eq!(canonical(result), want, "row {i}: reconnect changed the result");
    }
    drop((w, r));
    shutdown(addr, handle);
}

#[test]
fn paginated_results_reassemble_out_of_order_completions_row_major() {
    let (addr, handle) = start();
    let local_sched = Scheduler::new(2, None);
    let (mut w, mut r) = connect(addr);
    // Eight rows of varying cost through four queue workers with a wide
    // window: completions land out of submission order, yet the results
    // pages must read back row-major.
    let nbs: [u64; 8] = [11, 4, 9, 5, 10, 6, 8, 7];
    let mut req = String::from(r#"{"cmd":"sweep","workloads":["edm"],"maps":["bb"],"#);
    req.push_str(&format!(r#""nbs":[11,4,9,5,10,6,8,7],"backend":"serial","seed":{SEED},"#));
    req.push_str(r#""stream":false,"window":8}"#);
    send(&mut w, &req);
    let ack = recv(&mut r);
    assert_eq!(ack.get("jobs").and_then(Json::as_u64), Some(8), "{ack:?}");
    assert_eq!(ack.get("streaming").and_then(Json::as_bool), Some(false));
    let sid = ack.get("sweep").and_then(Json::as_u64).unwrap();

    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
    let rows = loop {
        assert!(std::time::Instant::now() < deadline, "sweep never completed");
        let mut rows: Vec<Json> = Vec::new();
        let mut cursor = 0u64;
        let done = loop {
            let get = format!(r#"{{"cmd":"results","sweep":{sid},"cursor":{cursor},"limit":3}}"#);
            send(&mut w, &get);
            let page = recv(&mut r);
            assert_eq!(page.get("ok").and_then(Json::as_bool), Some(true), "{page:?}");
            let chunk = page.get("results").and_then(Json::as_arr).unwrap();
            rows.extend(chunk.iter().cloned());
            match page.get("next_cursor").and_then(Json::as_u64) {
                Some(next) => cursor = next,
                None => break page.get("done").and_then(Json::as_bool) == Some(true),
            }
        };
        if done && rows.iter().all(|row| !matches!(row, Json::Null)) {
            break rows;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    };

    assert_eq!(rows.len(), nbs.len());
    for (i, row) in rows.iter().enumerate() {
        assert_eq!(row.get("job").and_then(Json::as_u64), Some(i as u64), "row order");
        assert_eq!(row.get("ok").and_then(Json::as_bool), Some(true), "{row:?}");
        let result = row.get("result").unwrap();
        let job = result.get("job").expect("result document embeds its job");
        let nb = job.get("nb").and_then(Json::as_u64);
        assert_eq!(nb, Some(nbs[i]), "row {i} must hold the row-major job, not arrival order");
        let want = local(&local_sched, "edm", nbs[i], "bb");
        assert_eq!(canonical(result), want, "row {i}: wire and local results differ");
    }
    drop((w, r));
    shutdown(addr, handle);
}
