//! The `edm_threshold` artifact: EDM tile + on-device ε-neighbour
//! *reduction* fused into one executable (L2 composition — the XLA
//! fusion the DESIGN.md §Perf section discusses). Exercises the
//! batcher's shared-scalar input path (`with_scalar`).
//!
//! For off-diagonal blocks the on-device count is exact (the strict
//! pair predicate passes the whole tile); we verify it against the
//! rust-side masked aggregation of the plain `edm_tile` artifact.

use std::path::PathBuf;

use simplexmap::coordinator::batcher::{TileBatcher, TileInput};
use simplexmap::runtime::{ExecutorService, TensorF32};
use simplexmap::workloads::EdmWorkload;

fn artifacts_dir() -> Option<PathBuf> {
    for candidate in ["artifacts", "../artifacts"] {
        let p = PathBuf::from(candidate);
        if p.join("manifest.json").exists() {
            return Some(p);
        }
    }
    None
}

#[test]
fn fused_threshold_matches_host_side_count() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("SKIP: artifacts/ not built");
        return;
    };
    let svc = ExecutorService::spawn(&dir).expect("executor");
    let handle = svc.handle();

    let nb = 8u64;
    let rho = 16u32;
    let w = EdmWorkload::generate(nb, rho, 5);
    let r2 = w.r2;

    // Off-diagonal blocks only (on-device count has no mask).
    let blocks: Vec<(u64, u64)> = (0..nb)
        .flat_map(|br| (0..br).map(move |bc| (bc, br)))
        .collect();
    let tiles: Vec<TileInput> = blocks
        .iter()
        .enumerate()
        .map(|(i, (bc, br))| TileInput {
            block_id: i as u64,
            inputs: vec![w.chunk(*br).to_vec(), w.chunk(*bc).to_vec()],
        })
        .collect();

    // Fused path: one output scalar per tile.
    let mut fused = TileBatcher::new(handle.clone(), "edm_threshold")
        .expect("batcher")
        .with_scalar(TensorF32::new(vec![], vec![r2]));
    let fused_out = fused.run(&tiles).expect("fused run");
    let fused_count: f64 = fused_out.iter().map(|o| o.data[0] as f64).sum();

    // Reference path: full tiles + host aggregation.
    let mut plain = TileBatcher::new(handle, "edm_tile").expect("batcher");
    let plain_out = plain.run(&tiles).expect("plain run");
    let host_count: u64 = plain_out
        .iter()
        .map(|o| {
            let (bc, br) = blocks[o.block_id as usize];
            w.aggregate_tile(bc, br, &o.data).0
        })
        .sum();

    assert_eq!(fused_count as u64, host_count, "fused vs host count");
    assert!(fused_count > 0.0, "scene must have neighbours");
    // The fused path moves R² per tile less data off-device: (R,R)
    // tile vs one scalar.
    let spec_plain = plain_out[0].data.len();
    assert_eq!(spec_plain, (rho * rho) as usize);
    assert_eq!(fused_out[0].data.len(), 1);
}

#[test]
fn fused_threshold_respects_radius() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("SKIP: artifacts/ not built");
        return;
    };
    let svc = ExecutorService::spawn(&dir).expect("executor");
    let w = EdmWorkload::generate(4, 16, 9);
    let tile = TileInput {
        block_id: 0,
        inputs: vec![w.chunk(1).to_vec(), w.chunk(0).to_vec()],
    };
    // Tiny radius → fewer neighbours than huge radius.
    let count_at = |r2: f32| -> f64 {
        let mut b = TileBatcher::new(svc.handle(), "edm_threshold")
            .unwrap()
            .with_scalar(TensorF32::new(vec![], vec![r2]));
        b.run(std::slice::from_ref(&tile)).unwrap()[0].data[0] as f64
    };
    let small = count_at(0.01);
    let large = count_at(1e6);
    assert!(small < large, "{small} !< {large}");
    assert_eq!(large as u64, 16 * 16, "everything within a huge radius");
}
