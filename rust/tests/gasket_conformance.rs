//! E15 conformance: the gasket-domain maps against brute-force gasket
//! enumeration — every gasket cell covered exactly once, zero overlap,
//! for every order k ≤ 6 — plus the closed-form space-efficiency
//! goldens against the bounding-box baseline ((4/3)^k improvement).

use std::collections::HashSet;

use simplexmap::maps::{
    alpha_m, map_by_name, map_names, map_names_for, space_efficiency_m, DomainKind,
    GasketBoundingBoxMap, GasketLambdaMap, MThreadMap,
};
use simplexmap::simplex::gasket::{
    enumerate_gasket, gasket_cell, gasket_order, gasket_rank, gasket_volume, in_gasket,
};
use simplexmap::util::proptest::{check_exhaustive, Prop};

/// Sweep a map's full parallel space; return (covered cells, filler,
/// duplicate count, escaped-domain count).
fn sweep(map: &dyn MThreadMap, nb: u64) -> (HashSet<(u64, u64)>, u64, u64, u64) {
    let mut seen = HashSet::new();
    let (mut filler, mut dups, mut escaped) = (0u64, 0u64, 0u64);
    for pass in 0..map.passes(nb) {
        for w in map.grid(nb, pass).iter() {
            match map.map_block(nb, pass, &w) {
                None => filler += 1,
                Some(d) => {
                    if !in_gasket(nb, d[0], d[1]) {
                        escaped += 1;
                    } else if !seen.insert((d[0], d[1])) {
                        dups += 1;
                    }
                }
            }
        }
    }
    (seen, filler, dups, escaped)
}

#[test]
fn lambda_gasket_partitions_every_order_up_to_6() {
    // The acceptance sweep: λ_Δ covers every gasket cell exactly once,
    // zero overlap, zero filler, for all k ≤ 6 — cross-checked against
    // the brute-force grid scan (built without the rank machinery).
    for k in 0..=6u32 {
        let nb = 1u64 << k;
        let (seen, filler, dups, escaped) = sweep(&GasketLambdaMap, nb);
        assert_eq!(dups, 0, "k={k}");
        assert_eq!(escaped, 0, "k={k}");
        assert_eq!(filler, 0, "k={k}: λ_Δ is exact");
        let brute: HashSet<(u64, u64)> = enumerate_gasket(nb).into_iter().collect();
        assert_eq!(seen.len() as u128, gasket_volume(k), "k={k}");
        assert_eq!(seen, brute, "k={k}");
    }
}

#[test]
fn bb_gasket_partitions_every_order_up_to_6() {
    for k in 0..=6u32 {
        let nb = 1u64 << k;
        let (seen, filler, dups, escaped) = sweep(&GasketBoundingBoxMap, nb);
        assert_eq!((dups, escaped), (0, 0), "k={k}");
        assert_eq!(filler as u128, 4u128.pow(k) - 3u128.pow(k), "k={k}");
        let brute: HashSet<(u64, u64)> = enumerate_gasket(nb).into_iter().collect();
        assert_eq!(seen, brute, "k={k}");
    }
}

#[test]
fn rank_bijection_agrees_with_enumeration() {
    // gasket_cell is λ_Δ's core; check it against the scan exhaustively
    // through the shared proptest harness.
    for k in 0..=6u32 {
        let nb = 1u64 << k;
        let brute: HashSet<(u64, u64)> = enumerate_gasket(nb).into_iter().collect();
        check_exhaustive(
            &format!("gasket-rank-roundtrip k={k}"),
            0..gasket_volume(k) as u64,
            |&t| {
                let (col, row) = gasket_cell(k, t);
                if !brute.contains(&(col, row)) {
                    return Prop::Fail(format!("rank {t} → ({col},{row}) ∉ G({k})"));
                }
                Prop::from_bool(
                    gasket_rank(k, col, row) == t,
                    &format!("rank({col},{row}) ≠ {t}"),
                )
            },
        );
    }
}

#[test]
fn space_efficiency_goldens_vs_bounding_box() {
    // Closed forms: λ_Δ is always 1.0; BB_Δ is (3/4)^k; the improvement
    // ratio is (4/3)^k — the acceptance criterion checks it within 1%
    // at k = 6 (it is exact: 4096/729).
    let lam = GasketLambdaMap;
    let bb = GasketBoundingBoxMap;
    for k in 0..=6u32 {
        let nb = 1u64 << k;
        assert!((space_efficiency_m(&lam, nb) - 1.0).abs() < 1e-12, "k={k}");
        assert!(
            (space_efficiency_m(&bb, nb) - 0.75f64.powi(k as i32)).abs() < 1e-12,
            "k={k}"
        );
        assert!(alpha_m(&lam, nb).abs() < 1e-12, "k={k}: zero waste");
    }
    let nb = 64u64; // k = 6
    assert_eq!(lam.parallel_volume(nb), 729);
    assert_eq!(bb.parallel_volume(nb), 4096);
    let improvement = bb.parallel_volume(nb) as f64 / lam.parallel_volume(nb) as f64;
    let closed = (4f64 / 3f64).powi(6);
    assert!(
        (improvement - closed).abs() / closed < 0.01,
        "{improvement} vs (4/3)^6 = {closed}"
    );
}

#[test]
fn domain_volume_overrides_the_simplex_closed_form() {
    // The engine's waste accounting divides by the map's own domain
    // volume — 3^k for gasket maps, not nb(nb+1)/2.
    for name in ["lambda-gasket", "bb-gasket"] {
        let map = map_by_name(2, name).unwrap();
        assert_eq!(map.domain(), DomainKind::Gasket, "{name}");
        for k in 0..=6u32 {
            let nb = 1u64 << k;
            assert_eq!(map.domain_volume(nb), gasket_volume(k), "{name} k={k}");
        }
        assert!(map.supports(64));
        assert!(!map.supports(48), "{name}: non-pow2 rejected");
    }
    assert_eq!(gasket_order(48), None);
}

#[test]
fn registry_and_listing_are_domain_scoped() {
    // Gasket names resolve at m = 2 but never appear in the simplex
    // listing the simplex conformance suites sweep — and vice versa,
    // the gasket listing is exactly the two gasket maps.
    let listed = map_names_for(2, DomainKind::Gasket);
    assert_eq!(listed, vec!["bb-gasket".to_string(), "lambda-gasket".to_string()]);
    for name in &listed {
        let map = map_by_name(2, name).unwrap();
        assert_eq!(map.name(), *name);
        assert_eq!(map.m(), 2);
    }
    for name in map_names(2) {
        let map = map_by_name(2, &name).unwrap();
        assert_eq!(map.domain(), DomainKind::Simplex, "{name}");
    }
    assert!(map_by_name(3, "lambda-gasket").is_none());
    assert!(map_names_for(4, DomainKind::Gasket).is_empty());
}

#[test]
fn order_zero_is_a_single_block() {
    // k = 0 edge: one cell, one block, both maps exact.
    for name in ["lambda-gasket", "bb-gasket"] {
        let map = map_by_name(2, name).unwrap();
        let (seen, filler, dups, escaped) = sweep(map.as_ref(), 1);
        assert_eq!(seen.len(), 1, "{name}");
        assert!(seen.contains(&(0, 0)), "{name}");
        assert_eq!((filler, dups, escaped), (0, 0, 0), "{name}");
    }
}
