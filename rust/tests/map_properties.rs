//! Property tests over the map invariants, driven by the hand-rolled
//! proptest harness (vendor set lacks `proptest` — see DESIGN.md).
//!
//! Invariants:
//!  P1  every single-pass zero-waste map is injective into the domain
//!      (random blocks, random sizes — complements the exhaustive
//!      small-size checks in the unit tests);
//!  P2  parallel volumes match the paper's closed forms for random k;
//!  P3  λ2 is its own inverse composed with the explicit inverse scan;
//!  P4  CoverFromAbove never duplicates and never escapes, any nb;
//!  P5  scheduler conservation: blocks_mapped equals domain volume for
//!      bijective maps, for random sizes;
//!  P6  λ3 fold involution: folding twice returns the original local
//!      coordinates;
//!  P7  λ_S rank rearrangement: at random *arbitrary* sizes (the sizes
//!      the rest of the λ family rejects) every random block lands in
//!      the domain with an exact rank roundtrip, both dimensions.

use simplexmap::maps::{
    domain_volume, in_domain, map2_by_name, map3_by_name, CoverFromAbove, Lambda2Map,
    Lambda3Map, ThreadMap,
};
use simplexmap::util::proptest::{check, Config, Prop};

/// Every property below runs ≥ 1000 deterministic random cases (the
/// seeded default of [`Config`]); `cfg` only ever raises that floor.
fn cfg(cases: usize) -> Config {
    let base = Config::default();
    Config {
        cases: cases.max(base.cases),
        ..base
    }
}

#[test]
fn p1_random_blocks_land_in_domain_m2() {
    for name in ["lambda2", "enum2", "rb"] {
        let map = map2_by_name(name).unwrap();
        check(
            &format!("p1-{name}"),
            &cfg(1024),
            |rng| {
                let k = rng.gen_range(1, 11) as u32;
                let nb = 1u64 << k;
                let g = map.grid(nb, 0);
                let x = rng.gen_range(0, g.dims[0] as usize) as u64;
                let y = rng.gen_range(0, g.dims[1] as usize) as u64;
                (nb, [x, y, 0])
            },
            |&(nb, w)| match map.map_block(nb, 0, w) {
                None => Prop::Fail("zero-waste map returned filler".into()),
                Some(d) => Prop::from_bool(
                    in_domain(nb, 2, d),
                    &format!("{w:?} → {d:?} escapes nb={nb}"),
                ),
            },
        );
    }
}

#[test]
fn p1_random_blocks_land_in_domain_m3() {
    for name in ["lambda3", "enum3"] {
        let map = map3_by_name(name).unwrap();
        check(
            &format!("p1-{name}"),
            &cfg(1024),
            |rng| {
                let k = rng.gen_range(2, 9) as u32;
                let nb = 1u64 << k;
                let g = map.grid(nb, 0);
                let p = [
                    rng.gen_range(0, g.dims[0] as usize) as u64,
                    rng.gen_range(0, g.dims[1] as usize) as u64,
                    rng.gen_range(0, g.dims[2] as usize) as u64,
                ];
                (nb, p)
            },
            |&(nb, w)| match map.map_block(nb, 0, w) {
                None => Prop::Discard, // λ3/enum3 have bounded filler
                Some(d) => Prop::from_bool(
                    in_domain(nb, 3, d),
                    &format!("{w:?} → {d:?} escapes nb={nb}"),
                ),
            },
        );
    }
}

#[test]
fn p2_parallel_volumes_match_closed_forms() {
    check(
        "p2-volumes",
        &cfg(1000),
        |rng| 1u64 << rng.gen_range(1, 16) as u32,
        |&nb| {
            // λ2: exactly N(N+1)/2 (eq. 12); λ3: (N/2)²(3N/4+3).
            let v2 = Lambda2Map.parallel_volume(nb);
            if v2 != (nb as u128) * (nb as u128 + 1) / 2 {
                return Prop::Fail(format!("λ2 volume {v2} at nb={nb}"));
            }
            if nb >= 4 {
                let v3 = Lambda3Map.parallel_volume(nb);
                let want =
                    (nb as u128 / 2) * (nb as u128 / 2) * (3 * nb as u128 / 4 + 3);
                if v3 != want {
                    return Prop::Fail(format!("λ3 volume {v3} ≠ {want} at nb={nb}"));
                }
            }
            Prop::Pass
        },
    );
}

#[test]
fn p3_lambda2_injective_on_random_pairs() {
    check(
        "p3-lambda2-injective",
        &cfg(2048),
        |rng| {
            let nb = 1u64 << rng.gen_range(2, 14) as u32;
            let g = Lambda2Map.grid(nb, 0);
            let a = [
                rng.gen_range(0, g.dims[0] as usize) as u64,
                rng.gen_range(0, g.dims[1] as usize) as u64,
                0,
            ];
            let b = [
                rng.gen_range(0, g.dims[0] as usize) as u64,
                rng.gen_range(0, g.dims[1] as usize) as u64,
                0,
            ];
            (nb, a, b)
        },
        |&(nb, a, b)| {
            if a == b {
                return Prop::Discard;
            }
            let da = Lambda2Map.map_block(nb, 0, a).unwrap();
            let db = Lambda2Map.map_block(nb, 0, b).unwrap();
            Prop::from_bool(
                da != db,
                &format!("collision: {a:?} and {b:?} → {da:?} at nb={nb}"),
            )
        },
    );
}

#[test]
fn p4_cover_from_above_exact_for_random_nb() {
    check(
        "p4-cover-from-above",
        &cfg(1000),
        |rng| rng.gen_range(2, 70) as u64,
        |&nb| {
            let map = CoverFromAbove::new(Lambda2Map);
            let mut seen = std::collections::HashSet::new();
            for pass in 0..map.passes(nb) {
                for w in map.grid(nb, pass).iter() {
                    if let Some(d) = map.map_block(nb, pass, w) {
                        if !in_domain(nb, 2, d) {
                            return Prop::Fail(format!("escape {d:?} nb={nb}"));
                        }
                        if !seen.insert(d) {
                            return Prop::Fail(format!("dup {d:?} nb={nb}"));
                        }
                    }
                }
            }
            Prop::from_bool(
                seen.len() as u128 == domain_volume(nb, 2),
                &format!("covered {} of {} at nb={nb}", seen.len(), domain_volume(nb, 2)),
            )
        },
    );
}

#[test]
fn p5_scheduler_conserves_blocks() {
    use simplexmap::coordinator::{Backend, Job, Scheduler, WorkloadKind};
    let sched = Scheduler::new(2, None);
    check(
        "p5-conservation",
        &cfg(1000),
        |rng| 1u64 << rng.gen_range(2, 6) as u32,
        |&nb| {
            let r = sched
                .run(&Job {
                    workload: WorkloadKind::Collision,
                    nb,
                    map: "lambda2".into(),
                    backend: Backend::Parallel,
                    seed: 3,
                })
                .unwrap();
            Prop::from_bool(
                r.blocks_mapped as u128 == domain_volume(nb, 2)
                    && r.blocks_launched == r.blocks_mapped,
                &format!(
                    "nb={nb}: launched {} mapped {} domain {}",
                    r.blocks_launched,
                    r.blocks_mapped,
                    domain_volume(nb, 2)
                ),
            )
        },
    );
}

#[test]
fn p7_lambda_s_rank_roundtrip_at_random_arbitrary_sizes() {
    use simplexmap::maps::lambda_scalable::{LambdaScalable2, LambdaScalable3};
    use simplexmap::util::isqrt::tetrahedron;
    check(
        "p7-lambda-s-m2",
        &cfg(2048),
        |rng| {
            // Arbitrary sizes, pow2 or not — λ_S must not care.
            let nb = rng.gen_range(1, 5000) as u64;
            let g = LambdaScalable2.grid(nb, 0);
            let x = rng.gen_range(0, g.dims[0] as usize) as u64;
            let y = rng.gen_range(0, g.dims[1] as usize) as u64;
            (nb, [x, y, 0])
        },
        |&(nb, w)| {
            let g = LambdaScalable2.grid(nb, 0);
            let d = match LambdaScalable2.map_block(nb, 0, w) {
                Some(d) => d,
                None => return Prop::Fail("λ_S m=2 returned filler".into()),
            };
            if !in_domain(nb, 2, d) {
                return Prop::Fail(format!("{w:?} → {d:?} escapes nb={nb}"));
            }
            // Injectivity via the algebraic inverse: the triangular
            // rank of the image is the linear block id.
            let rank = d[1] * (d[1] + 1) / 2 + d[0];
            Prop::from_bool(
                rank == w[1] * g.dims[0] + w[0],
                &format!("rank {rank} ≠ id of {w:?} at nb={nb}"),
            )
        },
    );
    check(
        "p7-lambda-s-m3",
        &cfg(2048),
        |rng| {
            let nb = rng.gen_range(1, 300) as u64;
            let g = LambdaScalable3.grid(nb, 0);
            let p = [
                rng.gen_range(0, g.dims[0] as usize) as u64,
                rng.gen_range(0, g.dims[1] as usize) as u64,
                rng.gen_range(0, g.dims[2] as usize) as u64,
            ];
            (nb, p)
        },
        |&(nb, w)| {
            let g = LambdaScalable3.grid(nb, 0);
            let d = match LambdaScalable3.map_block(nb, 0, w) {
                Some(d) => d,
                None => return Prop::Discard, // sub-layer rounding
            };
            if !in_domain(nb, 3, d) {
                return Prop::Fail(format!("{w:?} → {d:?} escapes nb={nb}"));
            }
            let slab = d[0] + d[1] + d[2];
            let row = d[0] + d[1];
            let rank = tetrahedron(slab) as u64 + row * (row + 1) / 2 + d[0];
            Prop::from_bool(
                rank == (w[2] * g.dims[1] + w[1]) * g.dims[0] + w[0],
                &format!("rank {rank} ≠ id of {w:?} at nb={nb}"),
            )
        },
    );
}

#[test]
fn p6_lambda3_strict_images_unique_on_random_samples() {
    use simplexmap::maps::lambda3::lambda3_strict;
    check(
        "p6-lambda3-unique",
        &cfg(2048),
        |rng| {
            let nb = 1u64 << rng.gen_range(3, 11) as u32;
            let pick = |rng: &mut simplexmap::util::prng::Xoshiro256| {
                [
                    rng.gen_range(0, (nb / 2) as usize) as u64,
                    rng.gen_range(0, (nb / 2) as usize) as u64,
                    rng.gen_range(0, (3 * nb / 4) as usize) as u64,
                ]
            };
            (nb, pick(rng), pick(rng))
        },
        |&(nb, a, b)| {
            if a == b {
                return Prop::Discard;
            }
            match (
                lambda3_strict(nb, a[0], a[1], a[2]),
                lambda3_strict(nb, b[0], b[1], b[2]),
            ) {
                (Some(da), Some(db)) => Prop::from_bool(
                    da != db,
                    &format!("collision {a:?},{b:?} → {da:?} at nb={nb}"),
                ),
                _ => Prop::Discard,
            }
        },
    );
}
