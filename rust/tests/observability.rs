//! End-to-end observability: spans recorded through the real job
//! lifecycle (server dispatch → queue → scheduler → launcher lanes)
//! and exported as Chrome trace JSON via the server's trace command.
//!
//! This suite owns the *global* span recorder (lib unit tests only
//! touch local `SpanRecorder` instances): it runs in its own test
//! binary, and every count assertion is `≥`/containment so tests in
//! this process stay order-independent.

use std::sync::Arc;

use simplexmap::coordinator::server::{dispatch, ServerCtx};
use simplexmap::coordinator::{span, QueueConfig, Scheduler};
use simplexmap::util::json::{self, Json};

#[test]
fn spans_flow_from_jobs_to_the_server_trace_command() {
    let mut sched = Scheduler::new(2, None);
    sched.profile_lanes = true;
    let ctx = ServerCtx::new(Arc::new(sched), QueueConfig::default());

    // A client can switch recording on over the wire…
    let r = dispatch(r#"{"cmd":"trace","enable":true}"#, &ctx);
    assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true), "{r}");
    assert_eq!(r.get("enabled").and_then(Json::as_bool), Some(true));
    assert!(span::global().enabled());

    // …run jobs…
    for req in [
        r#"{"cmd":"run","workload":"edm","nb":8,"map":"lambda2"}"#,
        r#"{"cmd":"run","workload":"collision","nb":8,"map":"bb"}"#,
    ] {
        let r = dispatch(req, &ctx);
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true), "{r}");
    }

    // …and pull the trace without restarting anything.
    let r = dispatch(r#"{"cmd":"trace","n":512}"#, &ctx);
    assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true), "{r}");
    assert!(r.get("spans").and_then(Json::as_u64).unwrap() >= 2);

    // The document round-trips through our own parser, and the whole
    // lifecycle is present: accept (server), queue_wait (queue), job
    // (scheduler), fused_sweep and per-lane intervals (engine).
    let text = r.get("trace").unwrap().to_string_compact();
    let back = json::parse(&text).expect("chrome trace must be valid JSON");
    let events = back.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty());
    let names: Vec<&str> = events
        .iter()
        .filter_map(|e| e.get("name").and_then(Json::as_str))
        .collect();
    for expected in ["accept", "queue_wait", "job", "fused_sweep"] {
        assert!(names.contains(&expected), "missing span '{expected}' in {names:?}");
    }
    assert!(
        names.iter().any(|n| n.starts_with("lane-")),
        "profiled run must emit per-lane spans: {names:?}"
    );

    // Job spans carry their scenario; the sweep nests under a job.
    let job = events
        .iter()
        .find(|e| e.get("name").and_then(Json::as_str) == Some("job"))
        .unwrap();
    assert_eq!(job.get("cat").and_then(Json::as_str), Some("scheduler"));
    let args = job.get("args").unwrap();
    assert!(args.get("workload").and_then(Json::as_str).is_some());
    assert!(args.get("map").and_then(Json::as_str).is_some());
    let job_ids: Vec<u64> = events
        .iter()
        .filter(|e| e.get("name").and_then(Json::as_str) == Some("job"))
        .filter_map(|e| e.get("args").unwrap().get("span_id").and_then(Json::as_u64))
        .collect();
    let sweep_parent = events
        .iter()
        .find(|e| e.get("name").and_then(Json::as_str) == Some("fused_sweep"))
        .and_then(|e| e.get("args").unwrap().get("parent").and_then(Json::as_u64))
        .unwrap();
    assert!(
        job_ids.contains(&sweep_parent),
        "fused_sweep parent {sweep_parent} not among job spans {job_ids:?}"
    );

    // Switch recording back off over the wire.
    let r = dispatch(r#"{"cmd":"trace","enable":false}"#, &ctx);
    assert_eq!(r.get("enabled").and_then(Json::as_bool), Some(false));
    assert!(!span::global().enabled());
}

#[test]
fn profiled_results_reach_clients_with_lane_fields() {
    let mut sched = Scheduler::new(3, None);
    sched.profile_lanes = true;
    let ctx = ServerCtx::new(Arc::new(sched), QueueConfig::default());
    let r = dispatch(
        r#"{"cmd":"run","workload":"edm","nb":16,"map":"lambda2"}"#,
        &ctx,
    );
    assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true), "{r}");
    let result = r.get("result").unwrap();
    assert!(result.get("lane_imbalance").and_then(Json::as_f64).unwrap() >= 1.0);
    let lanes = result.get("lane_profile").unwrap().as_arr().unwrap();
    assert!(!lanes.is_empty());
    let blocks: u64 = lanes
        .iter()
        .map(|l| l.get("blocks_processed").unwrap().as_u64().unwrap())
        .sum();
    assert_eq!(
        Some(blocks),
        result.get("blocks_launched").unwrap().as_u64(),
        "lane tallies cover the launch"
    );
    // The wire result stays round-trippable.
    assert!(json::parse(&r.to_string_compact()).is_ok());
}
