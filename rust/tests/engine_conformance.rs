//! E14 — unified-engine conformance: the fused streaming launch must
//! produce *identical launch accounting* (passes, blocks launched /
//! mapped, threads launched / predicated-off) and equal aggregation
//! outputs (exactly for counts; within float-reassociation tolerance
//! for f32-merged checksums) to the opt-in collect-then-execute flow —
//! for every registered map at m ∈ {2, 3, 4} and for every workload.
//!
//! Golden values are carried over from the PR 2 conformance layer
//! (λ_m m=4 β=2 at its first covered size nb=28: 31501 launched /
//! 31465 mapped / 36 filler).

use simplexmap::coordinator::{Backend, ExecMode, Job, JobResult, Scheduler, WorkloadKind};

fn job(w: WorkloadKind, nb: u64, map: &str) -> Job {
    Job {
        workload: w,
        nb,
        map: map.into(),
        backend: Backend::Parallel,
        seed: 29,
    }
}

/// Run one job in both modes and assert equivalence; returns the
/// streaming result for extra (golden-value) assertions.
fn assert_equivalent(w: WorkloadKind, nb: u64, map: &str) -> JobResult {
    let streaming = Scheduler::new(4, None);
    let mut collect = Scheduler::new(4, None);
    collect.exec_mode = ExecMode::Collect;
    let label = format!("{} nb={nb} map={map}", w.name());
    let a = streaming
        .run(&job(w, nb, map))
        .unwrap_or_else(|e| panic!("streaming {label}: {e}"));
    let b = collect
        .run(&job(w, nb, map))
        .unwrap_or_else(|e| panic!("collect {label}: {e}"));

    // Launch accounting must be bit-identical across modes.
    assert_eq!(a.passes, b.passes, "{label}: passes");
    assert_eq!(a.blocks_launched, b.blocks_launched, "{label}: launched");
    assert_eq!(a.blocks_mapped, b.blocks_mapped, "{label}: mapped");
    assert_eq!(a.threads_launched, b.threads_launched, "{label}: threads");
    assert_eq!(
        a.threads_predicated_off, b.threads_predicated_off,
        "{label}: predicated"
    );

    // Outputs: same keys, same values. Counts are exact; f32-merged
    // checksums may differ by reassociation across lane boundaries.
    assert_eq!(a.outputs.len(), b.outputs.len(), "{label}");
    for ((ka, va), (kb, vb)) in a.outputs.iter().zip(&b.outputs) {
        assert_eq!(ka, kb, "{label}");
        let exact = ka.contains("count") || ka.contains("population");
        if exact {
            assert_eq!(va, vb, "{label}: {ka}");
        } else {
            let tol = 1e-5 * va.abs().max(1.0);
            assert!(
                (va - vb).abs() <= tol,
                "{label}: {ka} {va} vs {vb}"
            );
        }
    }
    a
}

#[test]
fn every_m2_map_streams_equal_to_collect() {
    for map in simplexmap::maps::map_names(2) {
        assert_equivalent(WorkloadKind::Edm, 8, &map);
    }
}

#[test]
fn every_m3_map_streams_equal_to_collect() {
    for map in simplexmap::maps::map_names(3) {
        assert_equivalent(WorkloadKind::Triple, 8, &map);
    }
}

#[test]
fn every_m4_map_streams_equal_to_collect() {
    for map in simplexmap::maps::map_names(4) {
        assert_equivalent(WorkloadKind::KTuple(4), 4, &map);
    }
}

#[test]
fn every_workload_streams_equal_to_collect_under_canonical_maps() {
    for (w, nb, map) in [
        (WorkloadKind::Edm, 8u64, "lambda2"),
        (WorkloadKind::Collision, 8, "lambda2"),
        (WorkloadKind::NBody, 4, "lambda2"),
        (WorkloadKind::Cellular, 8, "lambda2"),
        (WorkloadKind::TriMatVec, 4, "lambda2"),
        (WorkloadKind::Triple, 4, "lambda3"),
        (WorkloadKind::KTuple(2), 8, "lambda2"),
        (WorkloadKind::KTuple(3), 4, "lambda3"),
        (WorkloadKind::KTuple(4), 4, "lambda-m"),
        (WorkloadKind::KTuple(5), 3, "lambda-m"),
    ] {
        assert_equivalent(w, nb, map);
    }
}

#[test]
fn lambda_m_golden_accounting_survives_the_unification() {
    // PR 2 golden values: λ_m (m=4, β=2) at its first covered size.
    let r = assert_equivalent(WorkloadKind::KTuple(4), 28, "lambda-m");
    assert_eq!(r.blocks_launched, 31501);
    assert_eq!(r.blocks_mapped, 31465);
    assert_eq!(r.blocks_launched - r.blocks_mapped, 36, "filler");
    // ρ_m = 2 at m = 4 → 16 threads per block.
    assert_eq!(r.threads_launched, 31501 * 16);
}

#[test]
fn streaming_outputs_match_brute_force_references() {
    // Mode equivalence alone could mask a shared bug; pin the fused
    // engine to the brute-force references directly.
    use simplexmap::workloads::{EdmWorkload, KTupleWorkload};
    let sched = Scheduler::new(4, None);

    let w = EdmWorkload::generate(8, sched.rho_for(2), 29);
    let (want_count, want_sum) = w.reference();
    let r = sched.run(&job(WorkloadKind::Edm, 8, "lambda2")).unwrap();
    assert_eq!(r.outputs[0].1 as u64, want_count);
    assert!((r.outputs[1].1 - want_sum).abs() < 1e-3 * want_sum.abs().max(1.0));

    let w = KTupleWorkload::generate(4, sched.rho_for(4), 4, 29);
    let want = w.reference();
    let r = sched.run(&job(WorkloadKind::KTuple(4), 4, "lambda-m")).unwrap();
    assert!((r.outputs[0].1 - want).abs() < 1e-9 * want.abs().max(1.0));
}
