//! Boundary regressions for the narrowing casts `simplexlint`'s `cast`
//! rule annotates (DESIGN.md §Static Analysis, E22): every `as u64`
//! in `maps/lambda_scalable.rs`, `maps/avril.rs` and `util/isqrt.rs`
//! carries a range proof in its allow-annotation, and these tests pin
//! each proof at the largest input the type can present — the audit
//! found no narrowing bug, and this file is the evidence that keeps it
//! that way.

use simplexmap::maps::avril::avril_map_isqrt;
use simplexmap::maps::lambda_scalable::{
    lambda_s2, scalable_width, LambdaScalable2, LambdaScalable3,
};
use simplexmap::maps::ThreadMap;
use simplexmap::simplex::volume::triangular;
use simplexmap::util::isqrt::{tetrahedral_root, tetrahedron, triangular_root};

/// Largest `r` with `T(r) ≤ u64::MAX` (`T(r) = r(r+1)/2` in u128).
fn max_triangular_row() -> u64 {
    let (mut lo, mut hi) = (1u64, u64::MAX);
    while lo < hi {
        let mid = lo + (hi - lo + 1) / 2;
        if triangular(mid) <= u64::MAX as u128 {
            lo = mid;
        } else {
            hi = mid - 1;
        }
    }
    lo
}

/// Largest `c` with `Tet(c) ≤ u64::MAX` (`Tet(c) = c(c+1)(c+2)/6`).
fn max_tetrahedral_cut() -> u64 {
    let (mut lo, mut hi) = (1u64, 10_000_000u64);
    while lo < hi {
        let mid = lo + (hi - lo + 1) / 2;
        if tetrahedron(mid) <= u64::MAX as u128 {
            lo = mid;
        } else {
            hi = mid - 1;
        }
    }
    lo
}

#[test]
fn triangular_root_exact_at_the_u64_edge() {
    let r = max_triangular_row();
    // The edge really is the edge.
    assert!(triangular(r) <= u64::MAX as u128);
    assert!(triangular(r + 1) > u64::MAX as u128);
    let tr = triangular(r) as u64;
    assert_eq!(triangular_root(tr), r);
    assert_eq!(triangular_root(tr - 1), r - 1);
    // The very top of the input type still floors into the edge row.
    assert_eq!(triangular_root(u64::MAX), r);
}

#[test]
fn triangular_root_exact_at_the_2pow32_row() {
    // λ_S2's supports() bound: rows stay below 2³² so r·(r+1) fits
    // u64. Pin exactness on both sides of that row.
    let r = 1u64 << 32;
    let tr = triangular(r) as u64;
    assert_eq!(triangular_root(tr), r);
    assert_eq!(triangular_root(tr - 1), r - 1);
}

#[test]
fn tetrahedral_root_exact_at_the_u64_edge() {
    let c = max_tetrahedral_cut();
    assert!(tetrahedron(c) <= u64::MAX as u128);
    assert!(tetrahedron(c + 1) > u64::MAX as u128);
    let tc = tetrahedron(c) as u64;
    assert_eq!(tetrahedral_root(tc), c);
    assert_eq!(tetrahedral_root(tc - 1), c - 1);
    assert_eq!(tetrahedral_root(u64::MAX), c);
}

#[test]
fn lambda_s2_top_rank_at_max_supported_nb() {
    // supports() admits every nb < 2³² and nothing above.
    let nb = (1u64 << 32) - 1;
    assert!(LambdaScalable2.supports(nb));
    assert!(!LambdaScalable2.supports(1u64 << 32));

    let width = scalable_width(nb);
    let grid = LambdaScalable2.grid(nb, 0);
    // Exact division: the half-width grid covers T(nb) with no waste.
    assert_eq!(grid.dims[0] as u128 * grid.dims[1] as u128, triangular(nb));

    // First block → the simplex origin.
    assert_eq!(LambdaScalable2.map_block(nb, 0, [0, 0, 0]), Some([0, 0, 0]));
    // Last block → the far corner (col = row = nb−1): the rank
    // arithmetic `row·(row+1)` peaked exactly at the supports() bound.
    let last = [grid.dims[0] - 1, grid.dims[1] - 1, 0];
    assert_eq!(LambdaScalable2.map_block(nb, 0, last), Some([nb - 1, nb - 1, 0]));
    // Rank T(nb−1) starts the last row.
    let k = triangular(nb - 1) as u64;
    let (col, row) = lambda_s2(k);
    assert_eq!((col, row), (0, nb - 1));
    assert_eq!(width, nb.div_ceil(2));
}

#[test]
fn lambda_s3_top_rank_at_max_supported_nb() {
    // Largest nb the m = 3 map admits: Tet(nb) + W² ≤ u64::MAX.
    let (mut lo, mut hi) = (1u64, 5_000_000u64);
    while lo < hi {
        let mid = lo + (hi - lo + 1) / 2;
        if LambdaScalable3.supports(mid) {
            lo = mid;
        } else {
            hi = mid - 1;
        }
    }
    let nb = lo;
    assert!(LambdaScalable3.supports(nb));
    assert!(!LambdaScalable3.supports(nb + 1));

    let width = scalable_width(nb);
    let grid = LambdaScalable3.grid(nb, 0);
    assert_eq!(grid.dims[0], width);
    assert_eq!(grid.dims[1], width);
    // The padded container covers the tetrahedron with < W² slack.
    let cells = width as u128 * width as u128 * grid.dims[2] as u128;
    assert!(cells >= tetrahedron(nb));
    assert!(cells - tetrahedron(nb) < width as u128 * width as u128);

    // Last real rank → a simplex point on the far slab x+y+z = nb−1.
    let k = (tetrahedron(nb) - 1) as u64;
    let w = [k % width, (k / width) % width, k / (width * width)];
    let p = LambdaScalable3.map_block(nb, 0, w).expect("last rank is real");
    assert_eq!(p[0] + p[1] + p[2], nb - 1);
    assert!(p.iter().all(|&x| x < nb));

    // One past the end (if the final layer is padded) is rejected, not
    // misassigned.
    if cells > tetrahedron(nb) {
        let k = tetrahedron(nb) as u64;
        let w = [k % width, (k / width) % width, k / (width * width)];
        assert_eq!(LambdaScalable3.map_block(nb, 0, w), None);
    }
}

#[test]
fn avril_isqrt_exact_at_2pow32_interactions() {
    // n = 2³² puts total = n(n−1)/2 within one bit of u64::MAX/2 —
    // far beyond both float cliffs (f32 at n ≈ 3000, f64 at n = 2²⁸).
    let n = 1u64 << 32;
    let total = n * (n - 1) / 2;
    assert_eq!(avril_map_isqrt(0, n), (0, 1));
    assert_eq!(avril_map_isqrt(n - 2, n), (0, n - 1)); // last of row 0
    assert_eq!(avril_map_isqrt(n - 1, n), (1, 2)); // first of row 1
    assert_eq!(avril_map_isqrt(total - 1, n), (n - 2, n - 1));
    // Row boundary deep in the triangle: the first pair of the second
    // half's diagonal row a = n/2.
    let a = n / 2;
    let row_start = a * n - a - a * (a - 1) / 2;
    assert_eq!(avril_map_isqrt(row_start, n), (a, a + 1));
    assert_eq!(avril_map_isqrt(row_start - 1, n), (a - 1, n - 1));
}
