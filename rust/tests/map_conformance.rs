//! Exhaustive map-conformance layer — the guarantee the maps module
//! header promises: for EVERY registered [`ThreadMap`] and EVERY
//! supported problem size up to the sweep bound, the images of all
//! valid parallel blocks (all passes) partition the block-level domain
//! exactly — no hole, no duplicate, no escape — and the filler count
//! equals the map's closed-form predicted waste.
//!
//! Sweep bounds: all `nb ≤ 64` for m=2 maps, all `nb ≤ 32` for m=3
//! maps (each map restricted to the sizes its `supports()` accepts);
//! the general-m section sweeps λ_m and BB_m at m ∈ {4, 5, 6} over the
//! first covered sizes of the gensearch level plans (E13).
//! This subsumes the per-map unit tests (which spot-check a few sizes)
//! and is the validation methodology of the follow-up papers: full
//! domain coverage before any benchmarking.
//!
//! Predicted waste (blocks discarded as `None` or grid padding):
//! - zero-waste maps (λ2, ENUM2, RB, Ries, CoverFromBelow): exactly 0 —
//!   `V(Π) = V(Δ)`, the paper's 2× headline for m=2;
//! - BB m=2 (eq. 4 finite form): `nb² − nb(nb+1)/2 = nb(nb−1)/2`;
//! - BB m=3: `nb³ − nb(nb+1)(nb+2)/6` (→ 5·V(Δ), the 6× headline);
//! - λ3 (eq. 24 container): `(nb/2)²(3nb/4+3) − V(Δ³)` (→ 12.5% slack);
//! - CoverFromAbove(λ2): `T(2^⌈log2 nb⌉) − T(nb)` (§III.A approach 1);
//! - ENUM3: z-layer rounding, `< (nb/2)²` padding blocks;
//! - Avril: strict pairs only (domain minus diagonal) + grid rounding;
//! - λ3-rec: cube overflow past each sub-tetrahedron's diagonal face
//!   (eq. 19's 1/5 extra volume, measured exactly).

use std::collections::HashSet;

use simplexmap::maps::{
    domain_volume, in_domain, in_domain_m, map2_by_name, map3_by_name, map_by_name,
    LambdaMMap, MThreadMap, ThreadMap, MAP2_NAMES, MAP3_NAMES,
};
use simplexmap::simplex::recursive_set::GeneralSetParams;
use simplexmap::simplex::volume::{next_pow2, simplex_volume, triangular};

const NB_MAX_M2: u64 = 64;
const NB_MAX_M3: u64 = 32;

/// Full-sweep accounting of one map at one size.
struct Coverage {
    covered: u128,
    dups: u64,
    escaped: u64,
    filler: u128,
    parallel: u128,
    images: HashSet<[u64; 3]>,
}

fn sweep(map: &dyn ThreadMap, nb: u64) -> Coverage {
    let mut images = HashSet::new();
    let mut dups = 0u64;
    let mut escaped = 0u64;
    let mut filler = 0u128;
    let mut parallel = 0u128;
    for pass in 0..map.passes(nb) {
        for w in map.grid(nb, pass).iter() {
            parallel += 1;
            match map.map_block(nb, pass, w) {
                None => filler += 1,
                Some(d) => {
                    if !in_domain(nb, map.m(), d) {
                        escaped += 1;
                    } else if !images.insert(d) {
                        dups += 1;
                    }
                }
            }
        }
    }
    Coverage {
        covered: images.len() as u128,
        dups,
        escaped,
        filler,
        parallel,
        images,
    }
}

/// Assert the map partitions the full block domain exactly at size nb.
fn assert_partitions(name: &str, map: &dyn ThreadMap, nb: u64, c: &Coverage) {
    let domain = domain_volume(nb, map.m());
    assert_eq!(c.dups, 0, "{name} nb={nb}: duplicate images");
    assert_eq!(c.escaped, 0, "{name} nb={nb}: images escape the domain");
    assert_eq!(
        c.covered, domain,
        "{name} nb={nb}: covered {} of {domain} blocks",
        c.covered
    );
    assert_eq!(
        c.parallel,
        map.parallel_volume(nb),
        "{name} nb={nb}: grid iteration disagrees with parallel_volume"
    );
}

/// The supported sizes of a map within [2, bound].
fn supported_sizes(map: &dyn ThreadMap, bound: u64) -> Vec<u64> {
    (1..=bound).filter(|&nb| map.supports(nb)).collect()
}

// ---- m = 2: every registered map, all nb ≤ 64 ------------------------

#[test]
fn every_m2_map_partitions_domain_at_every_supported_size() {
    for name in MAP2_NAMES {
        let map = map2_by_name(name).unwrap();
        let sizes = supported_sizes(map.as_ref(), NB_MAX_M2);
        assert!(!sizes.is_empty(), "{name}: supports no size ≤ {NB_MAX_M2}");
        for nb in sizes {
            let c = sweep(map.as_ref(), nb);
            if *name == "avril" {
                // Thread-space map over strict pairs: covers the domain
                // minus the nb diagonal blocks, exactly once.
                assert_eq!(c.dups, 0, "avril nb={nb}");
                assert_eq!(c.escaped, 0, "avril nb={nb}");
                assert_eq!(
                    c.covered,
                    domain_volume(nb, 2) - nb as u128,
                    "avril nb={nb}: strict pairs"
                );
                for d in &c.images {
                    assert!(d[0] < d[1], "avril nb={nb}: diagonal image {d:?}");
                }
            } else {
                assert_partitions(name, map.as_ref(), nb, &c);
            }
        }
    }
}

#[test]
fn zero_waste_m2_maps_have_exactly_zero_filler() {
    // The paper's m=2 claim: parallel space equals the data domain.
    for name in ["lambda2", "enum2", "rb", "ries", "below2", "lambda-s"] {
        let map = map2_by_name(name).unwrap();
        for nb in supported_sizes(map.as_ref(), NB_MAX_M2) {
            let c = sweep(map.as_ref(), nb);
            assert_eq!(c.filler, 0, "{name} nb={nb}: zero-waste map has filler");
            assert_eq!(
                map.parallel_volume(nb),
                domain_volume(nb, 2),
                "{name} nb={nb}: V(Π) ≠ V(Δ)"
            );
        }
    }
}

#[test]
fn bb2_filler_matches_eq4_closed_form_at_every_size() {
    // Exact predicted waste: nb(nb−1)/2 dead blocks — the finite form
    // of eq. 4 whose limit is the 2× claim of the abstract.
    let map = map2_by_name("bb").unwrap();
    for nb in 1..=NB_MAX_M2 {
        let c = sweep(map.as_ref(), nb);
        let nb_ = nb as u128;
        assert_eq!(c.filler, nb_ * (nb_ - 1) / 2, "bb2 nb={nb}");
        assert_eq!(c.parallel, nb_ * nb_, "bb2 nb={nb}");
        assert_eq!(c.covered, triangular(nb), "bb2 nb={nb}");
    }
}

#[test]
fn cover_from_above_filler_matches_rounding_waste() {
    // §III.A approach 1: run λ2 at 2^⌈log2 nb⌉, discard the overshoot.
    let map = map2_by_name("above2").unwrap();
    for nb in 2..=NB_MAX_M2 {
        let c = sweep(map.as_ref(), nb);
        let up = next_pow2(nb);
        assert_eq!(
            c.filler,
            triangular(up) - triangular(nb),
            "above2 nb={nb} (rounds to {up})"
        );
    }
}

#[test]
fn avril_filler_is_grid_rounding_only() {
    let map = map2_by_name("avril").unwrap();
    for nb in supported_sizes(map.as_ref(), NB_MAX_M2) {
        let c = sweep(map.as_ref(), nb);
        let strict = (nb as u128) * (nb as u128 - 1) / 2;
        assert_eq!(c.filler, c.parallel - strict, "avril nb={nb}");
    }
}

// ---- m = 3: every registered map, all nb ≤ 32 ------------------------

#[test]
fn every_m3_map_partitions_domain_at_every_supported_size() {
    for name in MAP3_NAMES {
        let map = map3_by_name(name).unwrap();
        let sizes = supported_sizes(map.as_ref(), NB_MAX_M3);
        assert!(!sizes.is_empty(), "{name}: supports no size ≤ {NB_MAX_M3}");
        for nb in sizes {
            let c = sweep(map.as_ref(), nb);
            assert_partitions(name, map.as_ref(), nb, &c);
        }
    }
}

#[test]
fn bb3_filler_matches_eq4_closed_form_at_every_size() {
    // Exact predicted waste: nb³ − Tet(nb); the ratio to the domain
    // approaches 3! − 1 = 5, i.e. the 6× headline.
    let map = map3_by_name("bb").unwrap();
    for nb in 1..=NB_MAX_M3 {
        let c = sweep(map.as_ref(), nb);
        let nb_ = nb as u128;
        assert_eq!(
            c.filler,
            nb_ * nb_ * nb_ - simplex_volume(nb, 3),
            "bb3 nb={nb}"
        );
    }
    let c = sweep(map.as_ref(), NB_MAX_M3);
    let ratio = c.filler as f64 / c.covered as f64;
    assert!((ratio - 5.0).abs() < 0.3, "bb3 waste ratio {ratio} vs 5");
}

#[test]
fn lambda3_filler_matches_eq24_container_slack() {
    // The λ3 container (N/2)×(N/2)×(3N/4+3): slack → 2/16 = 12.5%.
    let map = map3_by_name("lambda3").unwrap();
    for nb in supported_sizes(map.as_ref(), NB_MAX_M3) {
        let c = sweep(map.as_ref(), nb);
        let nb_ = nb as u128;
        let container = (nb_ / 2) * (nb_ / 2) * (3 * nb_ / 4 + 3);
        assert_eq!(c.parallel, container, "lambda3 nb={nb}");
        assert_eq!(c.filler, container - simplex_volume(nb, 3), "lambda3 nb={nb}");
    }
}

#[test]
fn lambda3_rec_cubes_are_disjoint_and_filler_is_cube_overflow() {
    // §III.B: cubes overflow their sub-tetrahedron's diagonal face; the
    // union of all passes still partitions the domain.
    let map = map3_by_name("lambda3-rec").unwrap();
    for nb in supported_sizes(map.as_ref(), NB_MAX_M3) {
        let c = sweep(map.as_ref(), nb);
        assert_eq!(
            c.filler,
            map.parallel_volume(nb) - domain_volume(nb, 3),
            "lambda3-rec nb={nb}"
        );
    }
}

// ---- λ_S, the scalable block-rearrangement family (E16) --------------

/// λ_S m=2 covers *every* size 1..=64 — the full-range sweep above
/// only exercises `supports()`-accepted sizes, so pin the claim here:
/// no size in range is skipped, and the grid is exactly T(nb) blocks.
#[test]
fn lambda_s_m2_supports_every_size_with_exact_grid() {
    let map = map2_by_name("lambda-s").unwrap();
    assert_eq!(
        supported_sizes(map.as_ref(), NB_MAX_M2).len() as u64,
        NB_MAX_M2,
        "λ_S must accept every nb ∈ [1, {NB_MAX_M2}]"
    );
    for nb in 1..=NB_MAX_M2 {
        assert_eq!(map.parallel_volume(nb), triangular(nb), "nb={nb}");
        let c = sweep(map.as_ref(), nb);
        assert_eq!(c.filler, 0, "nb={nb}: λ_S m=2 is zero-waste");
    }
}

/// λ_S m=3 covers every size 1..=32 with the closed-form container
/// waste `W²·⌈Tet(nb)/W²⌉ − Tet(nb) < W²` (final-layer rounding only).
#[test]
fn lambda_s_m3_filler_matches_closed_form_at_every_size() {
    let map = map3_by_name("lambda-s").unwrap();
    assert_eq!(
        supported_sizes(map.as_ref(), NB_MAX_M3).len() as u64,
        NB_MAX_M3,
        "λ_S must accept every nb ∈ [1, {NB_MAX_M3}]"
    );
    for nb in 1..=NB_MAX_M3 {
        let c = sweep(map.as_ref(), nb);
        let w = nb.div_ceil(2) as u128;
        let container = w * w * simplex_volume(nb, 3).div_ceil(w * w);
        assert_eq!(c.parallel, container, "lambda-s m=3 nb={nb}");
        assert_eq!(c.filler, container - simplex_volume(nb, 3), "nb={nb}");
        assert!(c.filler < w * w, "nb={nb}: more than one layer of waste");
    }
}

/// The E16 improvement goldens vs BB and the λ family (python-cross-
/// checked): λ_S m=2 is exactly T(nb)-tight like λ2 but at every nb;
/// λ_S m=3 launches exactly 1.125× fewer blocks than λ3's container at
/// nb = 32 and approaches the full 6× over BB.
#[test]
fn lambda_s_improvement_factors_match_closed_forms() {
    let ls2 = map2_by_name("lambda-s").unwrap();
    let bb2 = map2_by_name("bb").unwrap();
    let l2 = map2_by_name("lambda2").unwrap();
    for nb in [6u64, 17, 33, 64] {
        let imp = bb2.parallel_volume(nb) as f64 / ls2.parallel_volume(nb) as f64;
        let closed = 2.0 * nb as f64 / (nb as f64 + 1.0);
        assert!((imp - closed).abs() < 1e-12, "nb={nb}: {imp} vs {closed}");
    }
    // Equal footing with λ2 wherever λ2 exists at all.
    for nb in [4u64, 16, 64] {
        assert_eq!(ls2.parallel_volume(nb), l2.parallel_volume(nb), "nb={nb}");
    }
    let ls3 = map3_by_name("lambda-s").unwrap();
    let l3 = map3_by_name("lambda3").unwrap();
    let bb3 = map3_by_name("bb").unwrap();
    assert_eq!(ls3.parallel_volume(32), 6144);
    assert_eq!(l3.parallel_volume(32), 6912);
    let vs_l3 = l3.parallel_volume(32) as f64 / ls3.parallel_volume(32) as f64;
    assert!((vs_l3 - 1.125).abs() < 1e-12, "vs λ3: {vs_l3}");
    let vs_bb = bb3.parallel_volume(32) as f64 / ls3.parallel_volume(32) as f64;
    assert!((vs_bb - 16.0 / 3.0).abs() < 1e-12, "vs BB: {vs_bb}");
}

/// The precision acceptance row: at nb ≥ 2^24 (block ranks around
/// 2^53, where the unfixed f64 inverse provably flips a row — see
/// util::isqrt) λ_S block assignment stays exact. Verified via the
/// algebraic rank roundtrip at boundary blocks of huge grids.
#[test]
fn lambda_s_stays_exact_at_sizes_where_f64_flips() {
    let map = map2_by_name("lambda-s").unwrap();
    for nb in [1u64 << 24, (1 << 24) + 1, (1 << 27) + 5, 1 << 31] {
        assert!(map.supports(nb), "nb={nb}");
        let g = map.grid(nb, 0);
        let (w, h) = (g.dims[0], g.dims[1]);
        assert_eq!(w as u128 * h as u128, triangular(nb), "nb={nb}: exact grid");
        for (x, y) in [
            (0u64, 0u64),
            (w - 1, 0),
            (0, h - 1),
            (w - 1, h - 1),
            (w / 2, h / 2),
        ] {
            let d = map.map_block(nb, 0, [x, y, 0]).expect("zero waste");
            assert!(d[0] <= d[1] && d[1] < nb, "nb={nb} ({x},{y}) → {d:?}");
            // Rank roundtrip: row-major triangular rank == linear id.
            assert_eq!(
                d[1] as u128 * (d[1] as u128 + 1) / 2 + d[0] as u128,
                y as u128 * w as u128 + x as u128,
                "nb={nb} ({x},{y})"
            );
        }
    }
    // The corner case the naive float root gets wrong: the block just
    // below the row boundary at row 2^27 (k = T(2^27) − 1).
    let nb = (1u64 << 27) + 5;
    let w = map.grid(nb, 0).dims[0];
    let k = (1u64 << 27) * ((1 << 27) + 1) / 2 - 1;
    let d = map.map_block(nb, 0, [k % w, k / w, 0]).unwrap();
    assert_eq!(d[1], (1 << 27) - 1, "must stay on the row below");
    assert_eq!(d[0], d[1], "last block of its row (the diagonal)");
}

#[test]
fn enum3_padding_is_less_than_one_layer() {
    let map = map3_by_name("enum3").unwrap();
    for nb in supported_sizes(map.as_ref(), NB_MAX_M3) {
        let c = sweep(map.as_ref(), nb);
        let base = (nb as u128 / 2) * (nb as u128 / 2);
        assert!(
            c.filler < base,
            "enum3 nb={nb}: padding {} ≥ one base layer {base}",
            c.filler
        );
    }
}

// ---- m ≥ 4: λ_m and the m-dim bounding box (E13) ---------------------

/// Full-sweep accounting of a dynamic-m map at one size.
struct CoverageM {
    covered: u128,
    dups: u64,
    escaped: u64,
    filler: u128,
    parallel: u128,
}

fn sweep_m(map: &dyn MThreadMap, nb: u64) -> CoverageM {
    let mut images = HashSet::new();
    let mut dups = 0u64;
    let mut escaped = 0u64;
    let mut filler = 0u128;
    let mut parallel = 0u128;
    for pass in 0..map.passes(nb) {
        for w in map.grid(nb, pass).iter() {
            parallel += 1;
            match map.map_block(nb, pass, &w) {
                None => filler += 1,
                Some(d) => {
                    if !in_domain_m(nb, map.m(), &d) {
                        escaped += 1;
                    } else if !images.insert(d) {
                        dups += 1;
                    }
                }
            }
        }
    }
    CoverageM {
        covered: images.len() as u128,
        dups,
        escaped,
        filler,
        parallel,
    }
}

fn assert_partitions_m(name: &str, map: &dyn MThreadMap, nb: u64, c: &CoverageM) {
    let domain = domain_volume(nb, map.m());
    assert_eq!(c.dups, 0, "{name} nb={nb}: duplicate images");
    assert_eq!(c.escaped, 0, "{name} nb={nb}: images escape the domain");
    assert_eq!(
        c.covered, domain,
        "{name} nb={nb}: covered {} of {domain} blocks",
        c.covered
    );
    assert_eq!(
        c.parallel,
        map.parallel_volume(nb),
        "{name} nb={nb}: grid iteration disagrees with parallel_volume"
    );
}

/// λ_m partitions `Bm(N)` exactly at its first covered sizes, and the
/// measured filler equals the gensearch level plan's closed-form waste
/// `V(plan) − V(Δ)` — python-cross-checked: m=4 β=2 covers {28, 30, …}
/// with plans 31501/41356; m=5 β=32 covers {4, 9, 10, …}.
#[test]
fn lambda_m_partitions_bm_exactly_at_covered_sizes() {
    for (m, beta, sizes) in [
        (4u32, 2u32, vec![28u64, 30]),
        (5, 32, vec![4, 9, 10]),
    ] {
        let map = LambdaMMap::for_paper(m, beta);
        let params = GeneralSetParams::for_paper(m, beta as f64);
        assert_eq!(
            params.first_covered(2, 4096),
            Some(sizes[0]),
            "m={m} β={beta}: first covered size moved"
        );
        for nb in sizes {
            assert!(map.covered(nb), "m={m} β={beta} nb={nb}");
            let c = sweep_m(&map, nb);
            assert_partitions_m("lambda-m", &map, nb, &c);
            // Closed-form waste: the discretized eq. 25 volume minus
            // the simplex, exactly.
            let plan_volume = params.discrete_volume(nb).unwrap();
            assert_eq!(c.parallel, plan_volume, "m={m} nb={nb}");
            assert_eq!(
                c.filler,
                plan_volume - simplex_volume(nb, m),
                "m={m} nb={nb}: filler ≠ plan − domain"
            );
        }
    }
}

/// Cross-checked absolute numbers for the two headline sizes.
#[test]
fn lambda_m_waste_matches_python_cross_check() {
    let m4 = LambdaMMap::for_paper(4, 2);
    let c = sweep_m(&m4, 28);
    assert_eq!((c.parallel, c.filler), (31501, 36));
    let m5 = LambdaMMap::for_paper(5, 32);
    let c = sweep_m(&m5, 9);
    assert_eq!((c.parallel, c.filler), (1299, 12));
}

/// Below the first covered size λ_m falls back to §III.A's
/// cover-from-above: exact partition at every nb ≥ 2, with the filler
/// being the (larger) native plan minus the true domain.
#[test]
fn lambda_m_fallback_partitions_below_n0() {
    for (m, beta, nbs) in [(4u32, 2u32, vec![8u64, 29]), (5, 32, vec![5u64])] {
        let map = LambdaMMap::for_paper(m, beta);
        for nb in nbs {
            assert!(!map.covered(nb), "m={m} nb={nb} should need fallback");
            let native = map.native_size(nb).unwrap();
            assert!(native > nb);
            let c = sweep_m(&map, nb);
            assert_partitions_m("lambda-m (fallback)", &map, nb, &c);
            let plan = GeneralSetParams::for_paper(m, beta as f64)
                .discrete_volume(native)
                .unwrap();
            assert_eq!(c.filler, plan - simplex_volume(nb, m), "m={m} nb={nb}");
        }
    }
}

/// Acceptance: λ_m beats the m-dim bounding box by ≥ 3× in space
/// efficiency at the first covered size for m=4 (measured ≈ 19.5×).
#[test]
fn lambda_m_exceeds_bb_efficiency_threefold_at_first_covered() {
    use simplexmap::maps::{space_efficiency_m, BoundingBoxM};
    let map = LambdaMMap::for_paper(4, 2);
    let bb = BoundingBoxM::new(4);
    let nb = 28u64;
    let lam = space_efficiency_m(&map, nb);
    let bbe = space_efficiency_m(&bb, nb);
    assert!(lam / bbe >= 3.0, "λ_m {lam} vs BB {bbe}");
    assert!((lam - 31465.0 / 31501.0).abs() < 1e-12);
}

/// The m-dim bounding box partitions with eq. 4's waste at every size.
#[test]
fn bb_m_partitions_with_eq4_filler() {
    for m in [4u32, 5, 6] {
        let map = map_by_name(m, "bb").unwrap();
        for nb in [2u64, 3, 5] {
            let c = sweep_m(map.as_ref(), nb);
            assert_partitions_m("bb-m", map.as_ref(), nb, &c);
            assert_eq!(
                c.filler,
                (nb as u128).pow(m) - simplex_volume(nb, m),
                "m={m} nb={nb}"
            );
        }
    }
}

/// Registered adapters reproduce the fixed-m partition guarantee: the
/// unified registry's view of λ3 sweeps identically to the native one.
#[test]
fn adapted_lambda3_sweeps_like_the_fixed_map() {
    let fixed = map3_by_name("lambda3").unwrap();
    let adapted = map_by_name(3, "lambda3").unwrap();
    for nb in [4u64, 8, 16] {
        let cf = sweep(fixed.as_ref(), nb);
        let ca = sweep_m(adapted.as_ref(), nb);
        assert_eq!(cf.covered, ca.covered, "nb={nb}");
        assert_eq!(cf.filler, ca.filler, "nb={nb}");
        assert_eq!(cf.parallel, ca.parallel, "nb={nb}");
        assert_eq!(cf.dups + cf.escaped + ca.dups + ca.escaped, 0);
    }
}

// ---- cross-map agreement --------------------------------------------

#[test]
fn all_m2_maps_produce_the_same_image_set() {
    // Not just "a partition" — the SAME partition of the same domain,
    // so any workload sees identical block sets under every map.
    for nb in [2u64, 4, 8, 16, 32, 64] {
        let reference: HashSet<[u64; 3]> = sweep(map2_by_name("bb").unwrap().as_ref(), nb).images;
        for name in MAP2_NAMES {
            let map = map2_by_name(name).unwrap();
            if !map.supports(nb) || *name == "avril" {
                continue;
            }
            let got = sweep(map.as_ref(), nb).images;
            assert_eq!(got, reference, "{name} nb={nb}: image set differs from bb");
        }
    }
}

#[test]
fn all_m3_maps_produce_the_same_image_set() {
    for nb in [4u64, 8, 16, 32] {
        let reference: HashSet<[u64; 3]> = sweep(map3_by_name("bb").unwrap().as_ref(), nb).images;
        for name in MAP3_NAMES {
            let map = map3_by_name(name).unwrap();
            if !map.supports(nb) {
                continue;
            }
            let got = sweep(map.as_ref(), nb).images;
            assert_eq!(got, reference, "{name} nb={nb}: image set differs from bb");
        }
    }
}
