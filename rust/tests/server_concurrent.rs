//! Concurrent serving through the bounded job queue: ≥4 clients hammer
//! the TCP leader in parallel; every job must complete, every job must
//! pass through the queue, and the queue metrics must be exported.

use std::io::{BufRead, BufReader, Write};
use std::sync::Arc;

use simplexmap::coordinator::server::Server;
use simplexmap::coordinator::{QueueConfig, Scheduler};
use simplexmap::util::json;

#[test]
fn concurrent_clients_execute_in_parallel_through_the_queue() {
    let sched = Arc::new(Scheduler::new(2, None));
    let server = Server::with_queue(
        Arc::clone(&sched),
        QueueConfig {
            workers: 4,
            capacity: 64,
        },
    );
    let (tx, rx) = std::sync::mpsc::channel();
    let server_thread = std::thread::spawn(move || {
        server
            .serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap())
            .unwrap();
    });
    let addr = rx.recv().unwrap();

    const CLIENTS: usize = 6;
    const JOBS_PER_CLIENT: usize = 3;
    let mut clients = Vec::new();
    for c in 0..CLIENTS {
        clients.push(std::thread::spawn(move || {
            let conn = std::net::TcpStream::connect(addr).unwrap();
            let mut writer = conn.try_clone().unwrap();
            let mut reader = BufReader::new(conn);
            let mut line = String::new();
            for j in 0..JOBS_PER_CLIENT {
                let (workload, map, nb) = match (c + j) % 3 {
                    0 => ("edm", "lambda2", 8),
                    1 => ("collision", "bb", 8),
                    _ => ("trimatvec", "rb", 16),
                };
                let req = format!(
                    r#"{{"cmd":"run","workload":"{workload}","nb":{nb},"map":"{map}","seed":{c}}}"#
                );
                writer.write_all(req.as_bytes()).unwrap();
                writer.write_all(b"\n").unwrap();
                line.clear();
                reader.read_line(&mut line).unwrap();
                let resp = json::parse(line.trim()).unwrap();
                assert_eq!(
                    resp.get("ok").and_then(|v| v.as_bool()),
                    Some(true),
                    "client {c} job {j}: {line}"
                );
                assert!(
                    resp.get("result").and_then(|r| r.get("blocks_mapped")).is_some(),
                    "client {c} job {j}: {line}"
                );
            }
        }));
    }
    for c in clients {
        c.join().expect("client thread");
    }

    // Every job went through the queue and completed.
    let total = (CLIENTS * JOBS_PER_CLIENT) as u64;
    let snap = sched.metrics.snapshot();
    assert_eq!(snap.get("jobs_completed").unwrap().as_u64(), Some(total));
    assert_eq!(snap.get("jobs_queued").unwrap().as_u64(), Some(total));
    assert_eq!(snap.get("jobs_failed").unwrap().as_u64(), Some(0));
    assert_eq!(snap.get("queue_depth").unwrap().as_u64(), Some(0));
    assert_eq!(
        snap.get("queue_wait").unwrap().get("count").unwrap().as_u64(),
        Some(total)
    );

    // Phase histograms saw every job and report monotone quantiles.
    for phase in ["queue_wait", "job_wall"] {
        let p = snap.get(phase).unwrap();
        assert_eq!(
            p.get("count").unwrap().as_u64(),
            Some(total),
            "{phase} count"
        );
        let q = |k: &str| p.get(k).unwrap().as_f64().unwrap_or_else(|| panic!("{phase}.{k}"));
        assert!(q("p50_secs") <= q("p90_secs"), "{phase}");
        assert!(q("p90_secs") <= q("p99_secs"), "{phase}");
        assert!(q("p99_secs") <= q("p999_secs"), "{phase}");
        assert!(q("p50_secs") >= 0.0, "{phase}");
    }

    // Every (workload, map, backend) scenario the burst ran shows up as
    // a labeled series: 6 clients × 3 jobs over 3 scenarios, default
    // backend, 6 runs each.
    let series = snap.get("series").unwrap();
    for key in [
        "edm/lambda2/parallel",
        "collision/bb/parallel",
        "trimatvec/rb/parallel",
    ] {
        let s = series.get(key).unwrap_or_else(|| panic!("missing series {key}"));
        assert_eq!(s.get("count").unwrap().as_u64(), Some(6), "{key}");
        assert!(s.get("p50_secs").unwrap().as_f64().is_some(), "{key}");
    }

    // Shut the leader down cleanly.
    let conn = std::net::TcpStream::connect(addr).unwrap();
    let mut writer = conn.try_clone().unwrap();
    writer.write_all(b"{\"cmd\":\"shutdown\"}\n").unwrap();
    let mut line = String::new();
    BufReader::new(conn).read_line(&mut line).unwrap();
    server_thread.join().unwrap();
}
