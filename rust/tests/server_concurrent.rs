//! Concurrent serving through the bounded job queue: ≥4 clients hammer
//! the TCP leader in parallel; every job must complete, every job must
//! pass through the queue, and the queue metrics must be exported.

use std::io::{BufRead, BufReader, Write};
use std::sync::Arc;

use simplexmap::coordinator::server::Server;
use simplexmap::coordinator::{QueueConfig, Scheduler};
use simplexmap::util::json;

#[test]
fn concurrent_clients_execute_in_parallel_through_the_queue() {
    let sched = Arc::new(Scheduler::new(2, None));
    let server = Server::with_queue(
        Arc::clone(&sched),
        QueueConfig {
            workers: 4,
            capacity: 64,
        },
    );
    let (tx, rx) = std::sync::mpsc::channel();
    let server_thread = std::thread::spawn(move || {
        server
            .serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap())
            .unwrap();
    });
    let addr = rx.recv().unwrap();

    const CLIENTS: usize = 6;
    const JOBS_PER_CLIENT: usize = 3;
    let mut clients = Vec::new();
    for c in 0..CLIENTS {
        clients.push(std::thread::spawn(move || {
            let conn = std::net::TcpStream::connect(addr).unwrap();
            let mut writer = conn.try_clone().unwrap();
            let mut reader = BufReader::new(conn);
            let mut line = String::new();
            for j in 0..JOBS_PER_CLIENT {
                let (workload, map, nb) = match (c + j) % 3 {
                    0 => ("edm", "lambda2", 8),
                    1 => ("collision", "bb", 8),
                    _ => ("trimatvec", "rb", 16),
                };
                let req = format!(
                    r#"{{"cmd":"run","workload":"{workload}","nb":{nb},"map":"{map}","seed":{c}}}"#
                );
                writer.write_all(req.as_bytes()).unwrap();
                writer.write_all(b"\n").unwrap();
                line.clear();
                reader.read_line(&mut line).unwrap();
                let resp = json::parse(line.trim()).unwrap();
                assert_eq!(
                    resp.get("ok").and_then(|v| v.as_bool()),
                    Some(true),
                    "client {c} job {j}: {line}"
                );
                assert!(
                    resp.get("result").and_then(|r| r.get("blocks_mapped")).is_some(),
                    "client {c} job {j}: {line}"
                );
            }
        }));
    }
    for c in clients {
        c.join().expect("client thread");
    }

    // Every job went through the queue and completed.
    let total = (CLIENTS * JOBS_PER_CLIENT) as u64;
    let snap = sched.metrics.snapshot();
    assert_eq!(snap.get("jobs_completed").unwrap().as_u64(), Some(total));
    assert_eq!(snap.get("jobs_queued").unwrap().as_u64(), Some(total));
    assert_eq!(snap.get("jobs_failed").unwrap().as_u64(), Some(0));
    assert_eq!(snap.get("queue_depth").unwrap().as_u64(), Some(0));
    assert_eq!(
        snap.get("queue_wait").unwrap().get("count").unwrap().as_u64(),
        Some(total)
    );

    // Shut the leader down cleanly.
    let conn = std::net::TcpStream::connect(addr).unwrap();
    let mut writer = conn.try_clone().unwrap();
    writer.write_all(b"{\"cmd\":\"shutdown\"}\n").unwrap();
    let mut line = String::new();
    BufReader::new(conn).read_line(&mut line).unwrap();
    server_thread.join().unwrap();
}
