//! Cross-language contract test: execute every AOT artifact through
//! the Rust PJRT executor and compare against the golden vectors
//! aot.py computed with the jit'd JAX models.
//!
//! Requires `make artifacts`. If artifacts/ is absent the tests are
//! skipped (with a loud message) rather than failed, so `cargo test`
//! works in a fresh checkout; CI runs `make test` which builds
//! artifacts first.

use std::path::PathBuf;

use simplexmap::runtime::{Executor, TensorF32};
use simplexmap::util::json::{self, Json};

fn artifacts_dir() -> Option<PathBuf> {
    for candidate in ["artifacts", "../artifacts"] {
        let p = PathBuf::from(candidate);
        if p.join("manifest.json").exists() {
            return Some(p);
        }
    }
    None
}

fn load_goldens(dir: &PathBuf) -> Json {
    let text = std::fs::read_to_string(dir.join("goldens.json")).expect("goldens.json");
    json::parse(&text).expect("valid goldens.json")
}

fn as_f32_vec(j: &Json) -> Vec<f32> {
    j.as_arr()
        .expect("array")
        .iter()
        .map(|x| x.as_f64().expect("number") as f32)
        .collect()
}

macro_rules! skip_without_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn executor_loads_all_artifacts() {
    let dir = skip_without_artifacts!();
    let exe = Executor::load_all(&dir).expect("load artifacts");
    let names = exe.names();
    for expected in [
        "collision_tile",
        "edm_threshold",
        "edm_tile",
        "nbody_tile",
        "triple_tile",
    ] {
        assert!(names.contains(&expected), "missing {expected}: {names:?}");
    }
    assert!(exe.platform().to_lowercase().contains("cpu"));
}

#[test]
fn all_artifacts_match_jax_goldens() {
    let dir = skip_without_artifacts!();
    let exe = Executor::load_all(&dir).expect("load artifacts");
    let goldens = load_goldens(&dir);
    let Json::Obj(map) = &goldens else {
        panic!("goldens must be an object")
    };
    assert!(!map.is_empty());
    for (name, g) in map {
        let spec = exe.spec(name).expect("spec").clone();
        let input_vals: Vec<Vec<f32>> = g
            .get("inputs")
            .and_then(Json::as_arr)
            .expect("inputs")
            .iter()
            .map(as_f32_vec)
            .collect();
        let inputs: Vec<TensorF32> = input_vals
            .into_iter()
            .enumerate()
            .map(|(i, data)| TensorF32::new(spec.input_shapes[i].clone(), data))
            .collect();
        let want = as_f32_vec(g.get("output").expect("output"));
        let got = exe.run_f32(name, &inputs).expect("execute");
        assert_eq!(got.data.len(), want.len(), "{name}: length");
        let mut max_err = 0f32;
        for (a, b) in got.data.iter().zip(&want) {
            let scale = 1.0 + a.abs().max(b.abs());
            max_err = max_err.max((a - b).abs() / scale);
        }
        assert!(
            max_err < 2e-4,
            "{name}: max relative error {max_err} vs jax golden"
        );
        eprintln!("artifact '{name}': matches jax golden (max rel err {max_err:.2e})");
    }
}

#[test]
fn shape_validation_rejects_bad_inputs() {
    let dir = skip_without_artifacts!();
    let exe = Executor::load_all(&dir).expect("load artifacts");
    let spec = exe.spec("edm_tile").unwrap().clone();
    // Wrong number of inputs.
    assert!(exe.run_f32("edm_tile", &[]).is_err());
    // Wrong shape.
    let bad = TensorF32::zeros(vec![1, 2, 3]);
    let good = TensorF32::zeros(spec.input_shapes[0].clone());
    assert!(exe.run_f32("edm_tile", &[bad, good.clone()]).is_err());
    // Unknown artifact.
    assert!(exe.run_f32("nope", &[good]).is_err());
}

#[test]
fn repeated_execution_is_deterministic() {
    let dir = skip_without_artifacts!();
    let exe = Executor::load_all(&dir).expect("load artifacts");
    let spec = exe.spec("nbody_tile").unwrap().clone();
    let mk = |seed: u64| {
        let mut rng = simplexmap::util::prng::Xoshiro256::seed_from_u64(seed);
        let len: usize = spec.input_shapes[0].iter().product();
        TensorF32::new(
            spec.input_shapes[0].clone(),
            (0..len).map(|_| rng.gen_f32() - 0.5).collect(),
        )
    };
    let (a, b) = (mk(1), mk(2));
    let r1 = exe.run_f32("nbody_tile", &[a.clone(), b.clone()]).unwrap();
    let r2 = exe.run_f32("nbody_tile", &[a, b]).unwrap();
    assert_eq!(r1, r2);
}
