//! End-to-end serving through the poll reactor over real TCP: control
//! commands, pipelined runs, streamed sweep fan-out, cursor-paginated
//! results, capped-frame rejection, split-write reassembly, and clean
//! shutdown — the wire contract of the multiplexed serving tier.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;

use simplexmap::coordinator::{Reactor, ReactorConfig, Scheduler};
use simplexmap::util::json::{self, Json};

fn start(cfg: ReactorConfig) -> (Arc<Scheduler>, SocketAddr, std::thread::JoinHandle<()>) {
    let sched = Arc::new(Scheduler::new(2, None));
    let reactor = Reactor::with_config(Arc::clone(&sched), cfg);
    let (tx, rx) = std::sync::mpsc::channel();
    let handle = std::thread::spawn(move || {
        reactor
            .serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap())
            .unwrap();
    });
    (sched, rx.recv().unwrap(), handle)
}

fn connect(addr: SocketAddr) -> (TcpStream, BufReader<TcpStream>) {
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_nodelay(true).unwrap();
    let reader = BufReader::new(stream.try_clone().unwrap());
    (stream, reader)
}

fn send(w: &mut TcpStream, line: &str) {
    w.write_all(line.as_bytes()).unwrap();
    w.write_all(b"\n").unwrap();
    w.flush().unwrap();
}

fn recv(r: &mut BufReader<TcpStream>) -> Json {
    let mut line = String::new();
    let n = r.read_line(&mut line).unwrap();
    assert!(n > 0, "server closed the connection unexpectedly");
    json::parse(line.trim()).unwrap_or_else(|e| panic!("bad frame {line:?}: {e}"))
}

fn is_ok(j: &Json) -> bool {
    j.get("ok").and_then(Json::as_bool) == Some(true)
}

fn shutdown(addr: SocketAddr, handle: std::thread::JoinHandle<()>) {
    let (mut w, mut r) = connect(addr);
    send(&mut w, r#"{"cmd":"shutdown"}"#);
    assert!(is_ok(&recv(&mut r)), "shutdown must ack");
    drop((w, r));
    handle.join().expect("reactor thread must exit after shutdown");
}

#[test]
fn control_commands_and_pipelined_runs_answer_in_order() {
    let (_sched, addr, handle) = start(ReactorConfig::default());
    let (mut w, mut r) = connect(addr);

    send(&mut w, r#"{"cmd":"ping"}"#);
    let pong = recv(&mut r);
    assert!(is_ok(&pong));
    assert_eq!(pong.get("pong").and_then(Json::as_bool), Some(true));

    // Pipeline: two runs and a ping written back-to-back; replies must
    // come back in request order (slots), with the ping answering only
    // after both runs despite being instant.
    send(&mut w, r#"{"cmd":"run","workload":"edm","nb":8,"map":"lambda2","seed":1}"#);
    send(&mut w, r#"{"cmd":"run","workload":"edm","nb":4,"map":"bb","seed":2}"#);
    send(&mut w, r#"{"cmd":"ping"}"#);
    let first = recv(&mut r);
    let second = recv(&mut r);
    let third = recv(&mut r);
    assert!(is_ok(&first) && is_ok(&second) && is_ok(&third), "all three must succeed");
    let nb_of = |j: &Json| {
        let job = j.get("result").and_then(|r| r.get("job"))?;
        job.get("nb").and_then(Json::as_u64)
    };
    assert_eq!(nb_of(&first), Some(8), "first reply answers the first request");
    assert_eq!(nb_of(&second), Some(4));
    assert_eq!(third.get("pong").and_then(Json::as_bool), Some(true));

    // Errors are replies, not disconnects: the conn stays usable.
    send(&mut w, r#"{"cmd":"run","workload":"edm","nb":8,"map":"lambda2","priority":"urgent"}"#);
    let bad = recv(&mut r);
    assert!(!is_ok(&bad));
    let msg = bad.get("error").and_then(Json::as_str).unwrap();
    assert!(msg.contains("priority"), "{bad:?}");
    send(&mut w, r#"{"cmd":"run","workload":"edm"}"#);
    assert!(!is_ok(&recv(&mut r)), "invalid job must refuse");
    send(&mut w, r#"{"cmd":"dance"}"#);
    let unknown = recv(&mut r);
    assert!(!is_ok(&unknown));
    let msg = unknown.get("error").and_then(Json::as_str).unwrap();
    assert!(msg.contains("unknown cmd"), "{unknown:?}");
    send(&mut w, r#"{"cmd":"ping"}"#);
    assert!(is_ok(&recv(&mut r)), "conn survives all error replies");

    drop((w, r));
    shutdown(addr, handle);
}

#[test]
fn sweep_streams_every_row_exactly_once_then_a_done_frame() {
    let (sched, addr, handle) = start(ReactorConfig::default());
    let (mut w, mut r) = connect(addr);
    send(
        &mut w,
        r#"{"cmd":"sweep","workloads":["edm"],"maps":["lambda2","bb"],"nbs":[4,8],"seed":9}"#,
    );
    let ack = recv(&mut r);
    assert!(is_ok(&ack), "{ack:?}");
    let sid = ack.get("sweep").and_then(Json::as_u64).unwrap();
    assert_eq!(ack.get("jobs").and_then(Json::as_u64), Some(4));
    assert_eq!(ack.get("streaming").and_then(Json::as_bool), Some(true));

    let mut seen = [false; 4];
    loop {
        let frame = recv(&mut r);
        assert_eq!(frame.get("sweep").and_then(Json::as_u64), Some(sid));
        if frame.get("done").and_then(Json::as_bool) == Some(true) {
            assert_eq!(frame.get("jobs").and_then(Json::as_u64), Some(4));
            assert_eq!(frame.get("completed").and_then(Json::as_u64), Some(4));
            assert_eq!(frame.get("failed").and_then(Json::as_u64), Some(0));
            break;
        }
        let idx = frame.get("job").and_then(Json::as_u64).unwrap() as usize;
        assert!(!seen[idx], "row {idx} streamed twice");
        seen[idx] = true;
        assert!(is_ok(&frame));
        // Row-major expansion: maps × nbs ⇒ rows (lambda2,4) (lambda2,8)
        // (bb,4) (bb,8).
        let job = frame.get("result").and_then(|r| r.get("job")).unwrap();
        let expect_map = if idx < 2 { "lambda2" } else { "bb" };
        let expect_nb = if idx % 2 == 0 { 4 } else { 8 };
        assert_eq!(job.get("map").and_then(Json::as_str), Some(expect_map), "row {idx}");
        assert_eq!(job.get("nb").and_then(Json::as_u64), Some(expect_nb), "row {idx}");
        assert_eq!(job.get("seed").and_then(Json::as_u64), Some(9));
    }
    assert!(seen.iter().all(|s| *s), "every row must stream");

    // Serving metrics observed the sweep.
    let snap = sched.metrics.snapshot();
    assert_eq!(snap.get("sweeps_started").unwrap().as_u64(), Some(1));
    assert_eq!(snap.get("sweeps_completed").unwrap().as_u64(), Some(1));
    assert_eq!(snap.get("sweep_jobs_completed").unwrap().as_u64(), Some(4));
    assert_eq!(snap.get("sweep_wall").unwrap().get("count").unwrap().as_u64(), Some(1));

    drop((w, r));
    shutdown(addr, handle);
}

#[test]
fn non_streaming_sweep_pages_through_results_with_cursors() {
    let (_sched, addr, handle) = start(ReactorConfig::default());
    let (mut w, mut r) = connect(addr);
    let mut req = String::from(r#"{"cmd":"sweep","workloads":["edm"],"maps":["bb"],"#);
    req.push_str(r#""nbs":[4,8,12,16,20],"stream":false}"#);
    send(&mut w, &req);
    let ack = recv(&mut r);
    assert!(is_ok(&ack), "{ack:?}");
    assert_eq!(ack.get("streaming").and_then(Json::as_bool), Some(false));
    let sid = ack.get("sweep").and_then(Json::as_u64).unwrap();

    // Poll pages of 2 until the sweep reports done and no row is null.
    let expected_nbs = [4u64, 8, 12, 16, 20];
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
    'poll: loop {
        assert!(std::time::Instant::now() < deadline, "sweep never completed");
        let mut rows: Vec<Json> = Vec::new();
        let mut cursor = 0u64;
        loop {
            send(
                &mut w,
                &format!(r#"{{"cmd":"results","sweep":{sid},"cursor":{cursor},"limit":2}}"#),
            );
            let page = recv(&mut r);
            assert!(is_ok(&page), "{page:?}");
            assert_eq!(page.get("jobs").and_then(Json::as_u64), Some(5));
            assert_eq!(page.get("cursor").and_then(Json::as_u64), Some(cursor));
            let chunk = page.get("results").and_then(Json::as_arr).unwrap();
            assert!(chunk.len() <= 2, "limit respected");
            rows.extend(chunk.iter().cloned());
            match page.get("next_cursor").and_then(Json::as_u64) {
                Some(next) => {
                    assert_eq!(next, cursor + chunk.len() as u64);
                    cursor = next;
                }
                None => {
                    assert_eq!(rows.len(), 5, "pages must cover every row");
                    if page.get("done").and_then(Json::as_bool) == Some(true)
                        && rows.iter().all(|r| !matches!(r, Json::Null))
                    {
                        break 'poll check_rows(&rows, &expected_nbs);
                    }
                    std::thread::sleep(std::time::Duration::from_millis(10));
                    continue 'poll;
                }
            }
        }
    }

    // Unknown sweep ids answer an error, not a hang.
    send(&mut w, r#"{"cmd":"results","sweep":999}"#);
    let missing = recv(&mut r);
    assert!(!is_ok(&missing));
    let msg = missing.get("error").and_then(Json::as_str).unwrap();
    assert!(msg.contains("unknown sweep"), "{missing:?}");

    drop((w, r));
    shutdown(addr, handle);
}

/// Rows come back in row-major submission order regardless of the
/// order workers finished them.
fn check_rows(rows: &[Json], expected_nbs: &[u64]) {
    for (i, row) in rows.iter().enumerate() {
        assert_eq!(row.get("job").and_then(Json::as_u64), Some(i as u64));
        assert!(is_ok(row), "row {i}: {row:?}");
        let job = row.get("result").and_then(|r| r.get("job")).unwrap();
        assert_eq!(job.get("nb").and_then(Json::as_u64), Some(expected_nbs[i]), "row {i}");
    }
}

#[test]
fn oversized_frames_reject_cleanly_and_split_writes_reassemble() {
    let cfg = ReactorConfig {
        max_frame: 256,
        ..ReactorConfig::default()
    };
    let (sched, addr, handle) = start(cfg);
    let (mut w, mut r) = connect(addr);

    // An oversized frame: rejected with a bounded read, conn survives.
    let huge = format!("{{\"cmd\":\"run\",\"pad\":\"{}\"}}", "x".repeat(512));
    send(&mut w, &huge);
    let reply = recv(&mut r);
    assert!(!is_ok(&reply));
    let msg = reply.get("error").and_then(Json::as_str).unwrap();
    assert!(msg.contains("256 byte limit"), "{reply:?}");

    // A request split across many small writes with pauses reassembles
    // into one frame once the newline lands.
    let req = b"{\"cmd\":\"run\",\"workload\":\"edm\",\"nb\":8,\"map\":\"lambda2\"}\n";
    for chunk in req.chunks(7) {
        w.write_all(chunk).unwrap();
        w.flush().unwrap();
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    let run = recv(&mut r);
    assert!(is_ok(&run), "split-written request must execute: {run:?}");

    assert_eq!(sched.metrics.snapshot().get("frames_oversized").unwrap().as_u64(), Some(1));
    drop((w, r));
    shutdown(addr, handle);
}

/// Poll `{"cmd":"metrics"}` on `conn` until `pred` holds or the
/// deadline passes; returns the last snapshot either way.
fn await_metrics(
    w: &mut TcpStream,
    r: &mut BufReader<TcpStream>,
    pred: impl Fn(&Json) -> bool,
) -> Json {
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
    loop {
        send(w, r#"{"cmd":"metrics"}"#);
        let reply = recv(r);
        let snap = reply.get("metrics").expect("metrics reply").clone();
        if pred(&snap) || std::time::Instant::now() > deadline {
            return snap;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
}

fn counter(snap: &Json, name: &str) -> u64 {
    snap.get(name)
        .and_then(Json::as_u64)
        .unwrap_or_else(|| panic!("metrics missing {name}"))
}

#[test]
fn store_cap_refuses_admission_with_a_typed_error() {
    // A 4-row store: a 5-row sweep must be refused up front — typed
    // pushback at admission, never silent loss mid-sweep.
    let cfg = ReactorConfig {
        store_rows_cap: 4,
        ..ReactorConfig::default()
    };
    let (sched, addr, handle) = start(cfg);
    let (mut w, mut r) = connect(addr);

    let mut req = String::from(r#"{"cmd":"sweep","workloads":["edm"],"maps":["bb"],"#);
    req.push_str(r#""nbs":[4,5,6,7,8],"stream":false}"#);
    send(&mut w, &req);
    let refused = recv(&mut r);
    assert!(!is_ok(&refused), "{refused:?}");
    let msg = refused.get("error").and_then(Json::as_str).unwrap();
    assert!(msg.contains("results store full"), "{refused:?}");
    assert!(msg.contains("SIMPLEXMAP_STORE_CAP"), "{refused:?}");
    // A refused sweep starts nothing and accepts nothing.
    let snap = sched.metrics.snapshot();
    assert_eq!(snap.get("sweeps_started").unwrap().as_u64(), Some(0));
    assert_eq!(snap.get("jobs_accepted").unwrap().as_u64(), Some(0));

    // A fitting sweep works; once finished, its entry is LRU ground
    // that a later admission may reclaim (counted in store_evictions).
    let mut req = String::from(r#"{"cmd":"sweep","workloads":["edm"],"maps":["bb"],"#);
    req.push_str(r#""nbs":[4,5,6,7],"stream":false}"#);
    send(&mut w, &req);
    let ack = recv(&mut r);
    assert!(is_ok(&ack), "{ack:?}");
    let sid = ack.get("sweep").and_then(Json::as_u64).unwrap();
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
    loop {
        assert!(std::time::Instant::now() < deadline, "sweep never completed");
        send(&mut w, &format!(r#"{{"cmd":"results","sweep":{sid},"limit":4}}"#));
        let page = recv(&mut r);
        assert!(is_ok(&page), "{page:?}");
        if page.get("done").and_then(Json::as_bool) == Some(true) {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    let mut req = String::from(r#"{"cmd":"sweep","workloads":["edm"],"maps":["bb"],"#);
    req.push_str(r#""nbs":[9,10],"stream":false}"#);
    send(&mut w, &req);
    let ack2 = recv(&mut r);
    assert!(is_ok(&ack2), "finished entries must be evictable: {ack2:?}");
    let snap = await_metrics(&mut w, &mut r, |s| counter(s, "store_evictions") >= 1);
    assert!(counter(&snap, "store_evictions") >= 1, "{snap}");

    // And a results request naming nothing is an error, not a hang.
    send(&mut w, r#"{"cmd":"results"}"#);
    let bad = recv(&mut r);
    assert!(!is_ok(&bad));
    assert!(
        bad.get("error").and_then(Json::as_str).unwrap().contains("sweep id or token"),
        "{bad:?}"
    );

    drop((w, r));
    shutdown(addr, handle);
}

#[test]
fn expired_rows_retry_once_then_fail_and_are_counted() {
    // job_timeout_ms = 0: every row's start deadline is already past
    // when a worker pops it, so each row expires, retries exactly
    // job_retry_max times through the queue, then fails for real —
    // fully deterministic retry accounting.
    let cfg = ReactorConfig {
        job_timeout_ms: 0,
        job_retry_max: 1,
        ..ReactorConfig::default()
    };
    let (sched, addr, handle) = start(cfg);
    let (mut w, mut r) = connect(addr);
    send(
        &mut w,
        r#"{"cmd":"sweep","workloads":["edm"],"maps":["bb"],"nbs":[4,5,6]}"#,
    );
    let ack = recv(&mut r);
    assert!(is_ok(&ack), "{ack:?}");
    let mut failed_rows = 0u64;
    loop {
        let frame = recv(&mut r);
        if frame.get("done").and_then(Json::as_bool) == Some(true) {
            assert_eq!(frame.get("completed").and_then(Json::as_u64), Some(0));
            assert_eq!(frame.get("failed").and_then(Json::as_u64), Some(3));
            break;
        }
        assert!(!is_ok(&frame), "a 0ms deadline must expire every row: {frame:?}");
        let msg = frame.get("error").and_then(Json::as_str).unwrap();
        assert!(msg.contains("expired"), "{frame:?}");
        failed_rows += 1;
    }
    assert_eq!(failed_rows, 3);

    let snap = sched.metrics.snapshot();
    // 3 rows × (1 first attempt + 1 retry) = 6 expiries, 3 retries.
    assert_eq!(snap.get("jobs_retried").unwrap().as_u64(), Some(3), "{snap}");
    assert_eq!(snap.get("jobs_expired").unwrap().as_u64(), Some(6), "{snap}");
    assert_eq!(snap.get("jobs_failed").unwrap().as_u64(), Some(3), "{snap}");
    // No job ever ran, so the completed-job identity is 0 = 0 + 0 + 0.
    assert_eq!(snap.get("jobs_completed").unwrap().as_u64(), Some(0));
    assert_eq!(snap.get("results_delivered").unwrap().as_u64(), Some(0));
    assert_eq!(snap.get("results_stored").unwrap().as_u64(), Some(0));
    assert_eq!(snap.get("orphaned_results").unwrap().as_u64(), Some(0));

    drop((w, r));
    shutdown(addr, handle);
}

#[test]
fn completed_jobs_are_all_delivered_stored_or_orphaned() {
    let (_sched, addr, handle) = start(ReactorConfig::default());
    let (mut w, mut r) = connect(addr);

    // Two plain runs answered on a live connection → delivered.
    send(&mut w, r#"{"cmd":"run","workload":"edm","nb":8,"map":"lambda2"}"#);
    send(&mut w, r#"{"cmd":"run","workload":"edm","nb":4,"map":"bb"}"#);
    assert!(is_ok(&recv(&mut r)));
    assert!(is_ok(&recv(&mut r)));

    // A non-streaming sweep paged to completion → stored.
    send(
        &mut w,
        r#"{"cmd":"sweep","workloads":["edm"],"maps":["bb"],"nbs":[4,5,6],"stream":false}"#,
    );
    let ack = recv(&mut r);
    assert!(is_ok(&ack), "{ack:?}");
    let token = ack.get("token").and_then(Json::as_str).unwrap().to_string();

    // A run whose connection dies mid-job: the result must still be
    // accounted — delivered (conn object outlived the client), stored
    // (stashed under a run token), or, only if the store refused it,
    // orphaned. Never silently dropped.
    {
        let (mut w2, _r2) = connect(addr);
        send(&mut w2, r#"{"cmd":"run","workload":"edm","nb":16,"map":"bb"}"#);
        // w2/_r2 drop here: the client vanishes with the job in flight.
    }

    // All 6 jobs execute regardless; the identity must close exactly.
    let snap = await_metrics(&mut w, &mut r, |s| counter(s, "jobs_completed") >= 6);
    let completed = counter(&snap, "jobs_completed");
    assert_eq!(completed, 6, "{snap}");
    assert_eq!(
        completed,
        counter(&snap, "results_delivered")
            + counter(&snap, "results_stored")
            + counter(&snap, "orphaned_results"),
        "completed-job accounting identity: {snap}"
    );
    assert!(counter(&snap, "results_delivered") >= 2, "{snap}");
    assert!(counter(&snap, "results_stored") >= 3, "{snap}");

    // The sweep's rows page back by token, and the occupancy gauges
    // see the store (3 sweep rows; the stash, if any, adds to them).
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
    loop {
        assert!(std::time::Instant::now() < deadline, "sweep never completed");
        send(&mut w, &format!(r#"{{"cmd":"results","token":"{token}","limit":3}}"#));
        let page = recv(&mut r);
        assert!(is_ok(&page), "{page:?}");
        if page.get("done").and_then(Json::as_bool) == Some(true) {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    let snap = await_metrics(&mut w, &mut r, |s| counter(s, "store_rows") >= 3);
    assert!(counter(&snap, "store_rows") >= 3, "{snap}");
    assert!(counter(&snap, "store_sweeps") >= 1, "{snap}");

    drop((w, r));
    shutdown(addr, handle);
}

#[test]
fn concurrent_sweep_clients_lose_nothing() {
    let (sched, addr, handle) = start(ReactorConfig::default());
    const CLIENTS: usize = 8;
    const ROWS: usize = 6;
    let mut clients = Vec::new();
    for c in 0..CLIENTS {
        clients.push(std::thread::spawn(move || {
            let (mut w, mut r) = connect(addr);
            let mut req = String::from(r#"{"cmd":"sweep","workloads":["edm"],"maps":["bb"],"#);
            req.push_str(&format!(r#""nbs":[4,5,6,7,8,9],"seed":{c},"window":2}}"#));
            send(&mut w, &req);
            let ack = recv(&mut r);
            assert!(is_ok(&ack), "client {c}: {ack:?}");
            let mut seen = [false; ROWS];
            loop {
                let frame = recv(&mut r);
                if frame.get("done").and_then(Json::as_bool) == Some(true) {
                    assert_eq!(frame.get("completed").and_then(Json::as_u64), Some(ROWS as u64));
                    break;
                }
                let idx = frame.get("job").and_then(Json::as_u64).unwrap() as usize;
                assert!(!seen[idx], "client {c}: duplicate row {idx}");
                seen[idx] = true;
            }
            assert!(seen.iter().all(|s| *s), "client {c}: lost rows");
        }));
    }
    for c in clients {
        c.join().expect("sweep client");
    }
    let snap = sched.metrics.snapshot();
    let total = (CLIENTS * ROWS) as u64;
    assert_eq!(snap.get("sweep_jobs_completed").unwrap().as_u64(), Some(total));
    assert_eq!(snap.get("sweeps_completed").unwrap().as_u64(), Some(CLIENTS as u64));
    assert_eq!(snap.get("jobs_failed").unwrap().as_u64(), Some(0));
    assert_eq!(snap.get("queue_depth").unwrap().as_u64(), Some(0));
    shutdown(addr, handle);
}
