//! `space_efficiency` against the paper's closed forms at
//! nb ∈ {8, 64, 512, 4096} — the E1/E2/E6 numbers:
//!
//! - λ2 (and every zero-waste m=2 map): exactly 1.0 (eq. 12);
//! - BB m=2: `T(nb)/nb² = (nb+1)/(2nb)` → 1/2 (eq. 4, m=2);
//! - BB m=3: `Tet(nb)/nb³ = (nb+1)(nb+2)/(6nb²)` → 1/6 (eq. 4, m=3);
//! - λ3: `Tet(nb)/((nb/2)²(3nb/4+3))` → 8/9 (eq. 24's 12.5% slack).

use simplexmap::maps::{
    alpha, alpha_m, map2_by_name, map3_by_name, space_efficiency, space_efficiency_m,
    BoundingBox2, BoundingBox3, BoundingBoxM, Lambda2Map, Lambda3Map, LambdaMMap,
};
use simplexmap::simplex::volume::factorial;

const SIZES: [u64; 4] = [8, 64, 512, 4096];

#[test]
fn lambda2_efficiency_is_exactly_one() {
    for nb in SIZES {
        let e = space_efficiency(&Lambda2Map, nb);
        assert!((e - 1.0).abs() < 1e-12, "nb={nb}: eff={e}");
        assert!(alpha(&Lambda2Map, nb).abs() < 1e-12, "nb={nb}");
    }
}

#[test]
fn all_zero_waste_m2_maps_hit_efficiency_one() {
    for name in ["lambda2", "enum2", "rb", "ries", "below2", "lambda-s"] {
        let map = map2_by_name(name).unwrap();
        for nb in SIZES {
            assert!(map.supports(nb), "{name} must support pow2 {nb}");
            let e = space_efficiency(map.as_ref(), nb);
            assert!((e - 1.0).abs() < 1e-12, "{name} nb={nb}: eff={e}");
        }
    }
}

#[test]
fn lambda_s_m2_efficiency_is_one_at_arbitrary_sizes() {
    // The λ_S scalability row: exactly 1.0 at sizes no other zero-waste
    // map family covers uniformly (odd, prime, pow2±1 — every nb).
    let map = map2_by_name("lambda-s").unwrap();
    for nb in [3u64, 7, 63, 65, 100, 511, 513, 4095, 4097, 9973] {
        assert!(map.supports(nb), "nb={nb}");
        let e = space_efficiency(map.as_ref(), nb);
        assert!((e - 1.0).abs() < 1e-12, "nb={nb}: eff={e}");
        assert!(alpha(map.as_ref(), nb).abs() < 1e-12, "nb={nb}");
    }
}

#[test]
fn lambda_s_m3_efficiency_matches_closed_form_and_beats_lambda3() {
    // λ_S m=3: eff = Tet(nb) / (W²·⌈Tet(nb)/W²⌉) with W = ⌈nb/2⌉ —
    // above λ3's 8/9 container bound at every common size, and defined
    // at the odd sizes λ3 rejects.
    let map = map3_by_name("lambda-s").unwrap();
    for nb in SIZES {
        let w = nb.div_ceil(2) as u128;
        let tet = simplexmap::simplex::volume::tetrahedral(nb);
        let closed = tet as f64 / ((w * w * tet.div_ceil(w * w)) as f64);
        let e = space_efficiency(map.as_ref(), nb);
        assert!((e - closed).abs() < 1e-12, "nb={nb}: {e} vs {closed}");
        assert!(
            e > space_efficiency(&Lambda3Map, nb),
            "nb={nb}: λ_S must beat λ3's container"
        );
    }
    // And the waste vanishes asymptotically (sub-layer rounding only):
    // at nb = 4096 the efficiency is within 0.03% of 1 — effectively
    // the full 6× over BB, vs λ3's 16/3.
    let e = space_efficiency(map.as_ref(), 4096);
    assert!(e > 0.9997, "eff(4096)={e}");
    let imp = e / space_efficiency(&BoundingBox3, 4096);
    assert!(imp > 5.99 && imp <= 6.01, "improvement {imp}");
    // Odd-size coverage λ3 never had.
    assert!(map.supports(4097) && !Lambda3Map.supports(4097));
}

#[test]
fn bb2_efficiency_matches_closed_form_and_tends_to_half() {
    for nb in SIZES {
        let e = space_efficiency(&BoundingBox2, nb);
        let closed = (nb as f64 + 1.0) / (2.0 * nb as f64);
        assert!((e - closed).abs() < 1e-12, "nb={nb}: {e} vs {closed}");
    }
    // Convergence: each size strictly closer to 1/2, and within 0.02%
    // at nb = 4096.
    let effs: Vec<f64> = SIZES
        .iter()
        .map(|&nb| space_efficiency(&BoundingBox2, nb))
        .collect();
    for w in effs.windows(2) {
        assert!((w[1] - 0.5).abs() < (w[0] - 0.5).abs());
    }
    assert!((effs[3] - 0.5).abs() < 2e-4, "eff(4096)={}", effs[3]);
}

#[test]
fn bb3_efficiency_matches_closed_form_and_tends_to_sixth() {
    for nb in SIZES {
        let e = space_efficiency(&BoundingBox3, nb);
        let nbf = nb as f64;
        let closed = (nbf + 1.0) * (nbf + 2.0) / (6.0 * nbf * nbf);
        assert!((e - closed).abs() < 1e-12, "nb={nb}: {e} vs {closed}");
    }
    let effs: Vec<f64> = SIZES
        .iter()
        .map(|&nb| space_efficiency(&BoundingBox3, nb))
        .collect();
    for w in effs.windows(2) {
        assert!((w[1] - 1.0 / 6.0).abs() < (w[0] - 1.0 / 6.0).abs());
    }
    assert!((effs[3] - 1.0 / 6.0).abs() < 2e-4, "eff(4096)={}", effs[3]);
}

#[test]
fn lambda3_efficiency_approaches_eight_ninths() {
    // eq. 24: container = 9/8 of the domain asymptotically.
    for nb in SIZES {
        let e = space_efficiency(&Lambda3Map, nb);
        let nbf = nb as f64;
        let closed =
            (nbf * (nbf + 1.0) * (nbf + 2.0) / 6.0) / ((nbf / 2.0).powi(2) * (0.75 * nbf + 3.0));
        assert!((e - closed).abs() < 1e-12, "nb={nb}: {e} vs {closed}");
    }
    let e = space_efficiency(&Lambda3Map, 4096);
    assert!((e - 8.0 / 9.0).abs() < 2e-3, "eff(4096)={e}");
}

#[test]
fn headline_improvement_factors() {
    // The abstract's "2× and 6× more efficient than bounding-box".
    let nb = 4096;
    let m2 = space_efficiency(&Lambda2Map, nb) / space_efficiency(&BoundingBox2, nb);
    assert!((m2 - 2.0).abs() < 1e-3, "m=2 improvement {m2}");
    let m3 = space_efficiency(&Lambda3Map, nb) / space_efficiency(&BoundingBox3, nb);
    // λ3 carries its 12.5% container slack: 6 × 8/9 = 16/3 ≈ 5.33.
    assert!((m3 - 16.0 / 3.0).abs() < 2e-2, "m=3 improvement {m3}");
}

#[test]
fn enum3_and_lambda3_rec_efficiency_bounded() {
    for name in ["enum3", "lambda3-rec"] {
        let map = map3_by_name(name).unwrap();
        for nb in [8u64, 32] {
            let e = space_efficiency(map.as_ref(), nb);
            assert!(e > 0.5 && e <= 1.0, "{name} nb={nb}: eff={e}");
        }
    }
}

// ---- the general-m asymptote rows (§III.D / gensearch, E13) ----------

#[test]
fn bb_m_efficiency_tends_to_inverse_m_factorial() {
    // eq. 4: BB waste → m! − 1, i.e. efficiency → 1/m!. At nb = 4096
    // the finite form C(nb+m-1, m)/nb^m is within 1% of the limit.
    for m in 4..=6u32 {
        let bb = BoundingBoxM::new(m);
        let e = space_efficiency_m(&bb, 4096);
        let limit = 1.0 / factorial(m) as f64;
        assert!(
            (e / limit - 1.0).abs() < 0.01,
            "m={m}: eff={e} vs 1/m!={limit}"
        );
        // And each size is strictly closer to the limit than the last.
        let closer = space_efficiency_m(&bb, 512);
        assert!((e - limit).abs() < (closer - limit).abs(), "m={m}");
    }
}

#[test]
fn lambda_m_waste_tends_to_gensearch_limit() {
    // The executable λ_m's measured waste approaches the gensearch
    // asymptote β/(m!-β) (python cross-check: 0.0902 vs 0.0909 for
    // m=4 β=2; 0.3611 vs 0.3636 for m=5 β=32 — all at nb = 4096).
    for (m, beta) in [(4u32, 2u32), (4, 4), (5, 16), (5, 32)] {
        let map = LambdaMMap::for_paper(m, beta);
        assert!(map.covered(4096), "m={m} β={beta}");
        let waste = alpha_m(&map, 4096);
        let limit = beta as f64 / (factorial(m) as f64 - beta as f64);
        assert!(
            (waste - limit).abs() < 0.01,
            "m={m} β={beta}: waste={waste} vs limit={limit}"
        );
    }
}

#[test]
fn lambda_m_improvement_over_bb_approaches_m_factorial() {
    // The paper's §III.D headline: the recursive parallel space is
    // practically m! times more efficient than the bounding box (up to
    // the β/(m!-β) slack): eff ratio at 4096 ≈ m!/(1 + waste_limit).
    for (m, beta) in [(4u32, 2u32), (5, 16)] {
        let map = LambdaMMap::for_paper(m, beta);
        let bb = BoundingBoxM::new(m);
        let nb = 4096u64;
        let ratio = space_efficiency_m(&map, nb) / space_efficiency_m(&bb, nb);
        let limit = beta as f64 / (factorial(m) as f64 - beta as f64);
        let expect = factorial(m) as f64 / (1.0 + limit);
        assert!(
            (ratio / expect - 1.0).abs() < 0.02,
            "m={m} β={beta}: ratio={ratio} vs m!/(1+waste)={expect}"
        );
        assert!(ratio > 3.0, "m={m}: the acceptance floor");
    }
}

#[test]
fn gensearch_rows_agree_with_the_asymptote_table() {
    // The E9 rows' efficiency_vs_bb column is exactly m! − β under the
    // paper parametrization — the m!-vs-BB asymptote rows.
    let rows = simplexmap::gensearch::search((4, 7), &[2.0, 8.0, 32.0], 1 << 40);
    for r in &rows {
        let expect = factorial(r.m) as f64 - r.beta;
        assert!(
            (r.efficiency_vs_bb - expect).abs() < 1e-6 * expect,
            "m={} β={}: {} vs {expect}",
            r.m,
            r.beta,
            r.efficiency_vs_bb
        );
    }
    // And the executable n0 (n0_exec) exists whenever n0 does, at or
    // below the horizon-capped real-valued n0 … or earlier, because
    // integer rounding over-covers small sizes.
    for r in rows.iter().filter(|r| r.m <= 5) {
        assert!(r.n0_exec.is_some(), "m={} β={}", r.m, r.beta);
    }
}
