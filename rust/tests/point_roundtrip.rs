//! Round-trip conformance for the simplex ↔ ordered-index coordinate
//! changes in `simplex::point` — the bridges every 3-simplex workload
//! crosses between map output (simplex coordinates) and data indices
//! (strictly ordered tuples).
//!
//! `tet_triple_to_simplex(n, ·)` maps `{(i,j,k) : k<j<i<n}` onto
//! `Δ_{n-2}³ = {(x,y,z) : x+y+z ≤ n-3}` bijectively;
//! `simplex_to_tet_triple` is its inverse. Here both directions are
//! verified over the FULL block domain `B3(N) = {x+y+z ≤ N-1}` for
//! every `N ≤ 24` (embedding `B3(N) = Δ_{(N+2)-2}³`, i.e. `n = N+2`),
//! plus the m=2 pair bridge over every `N ≤ 64`.

use std::collections::HashSet;

use simplexmap::simplex::point::{
    lower_tet_contains, lower_tri_contains, simplex_to_tet_triple, simplex_to_tri_pair,
    tet_triple_to_simplex, tri_pair_to_simplex,
};
use simplexmap::simplex::volume::simplex_volume;

#[test]
fn tet_triple_roundtrip_over_full_b3_domain() {
    for cap in 1..=24u64 {
        // B3(cap) = {x+y+z ≤ cap-1} = Δ_cap³; ordered triples live in
        // [0, n) with n = cap + 2.
        let n = cap + 2;
        let mut seen = HashSet::new();
        for x in 0..cap {
            for y in 0..cap {
                for z in 0..cap {
                    if x + y + z > cap - 1 {
                        continue;
                    }
                    let (i, j, k) = simplex_to_tet_triple(n, x, y, z);
                    // Lands in the strict triple domain…
                    assert!(
                        lower_tet_contains(n, i, j, k),
                        "N={cap}: ({x},{y},{z}) → ({i},{j},{k}) not strict"
                    );
                    // …injectively…
                    assert!(seen.insert((i, j, k)), "N={cap}: duplicate ({i},{j},{k})");
                    // …and returns home exactly.
                    assert_eq!(
                        tet_triple_to_simplex(n, i, j, k),
                        (x, y, z),
                        "N={cap}: round trip broke at ({x},{y},{z})"
                    );
                }
            }
        }
        // Surjective: the image is the whole strict-triple set.
        assert_eq!(
            seen.len() as u128,
            simplex_volume(cap, 3),
            "N={cap}: image size"
        );
        let all_strict = (0..n)
            .flat_map(|i| (0..i).flat_map(move |j| (0..j).map(move |k| (i, j, k))))
            .count();
        assert_eq!(seen.len(), all_strict, "N={cap}: not onto");
    }
}

#[test]
fn tet_triple_inverse_direction_over_all_strict_triples() {
    for n in 3..=26u64 {
        let mut seen = HashSet::new();
        for i in 0..n {
            for j in 0..i {
                for k in 0..j {
                    let (x, y, z) = tet_triple_to_simplex(n, i, j, k);
                    assert!(x + y + z <= n - 3, "n={n}: ({i},{j},{k}) → ({x},{y},{z})");
                    assert!(seen.insert((x, y, z)), "n={n}: duplicate ({x},{y},{z})");
                    assert_eq!(simplex_to_tet_triple(n, x, y, z), (i, j, k), "n={n}");
                }
            }
        }
        assert_eq!(seen.len() as u128, simplex_volume(n - 2, 3), "n={n}");
    }
}

#[test]
fn tri_pair_roundtrip_over_full_b2_domain() {
    for cap in 1..=64u64 {
        let n = cap + 1; // B2(cap) = Δ_cap² ↔ strict pairs below n = cap+1
        let mut seen = HashSet::new();
        for x in 0..cap {
            for y in 0..cap {
                if x + y > cap - 1 {
                    continue;
                }
                let (row, col) = simplex_to_tri_pair(n, x, y);
                assert!(lower_tri_contains(n, row, col), "N={cap}: ({x},{y})");
                assert!(seen.insert((row, col)), "N={cap}: duplicate");
                assert_eq!(tri_pair_to_simplex(n, row, col), (x, y), "N={cap}");
            }
        }
        assert_eq!(seen.len() as u128, simplex_volume(cap, 2), "N={cap}");
    }
}
