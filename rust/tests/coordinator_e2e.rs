//! Whole-stack integration: scheduler → map → tile batcher → PJRT
//! (AOT Pallas kernels) → aggregation, cross-checked against both the
//! pure-Rust backend and the brute-force references.
//!
//! Requires `make artifacts`; skips (loudly) otherwise.

use std::path::PathBuf;

use simplexmap::coordinator::{Backend, Job, Scheduler, WorkloadKind};
use simplexmap::runtime::ExecutorService;
use simplexmap::workloads::{EdmWorkload, NBodyWorkload, TripleWorkload};

fn artifacts_dir() -> Option<PathBuf> {
    for candidate in ["artifacts", "../artifacts"] {
        let p = PathBuf::from(candidate);
        if p.join("manifest.json").exists() {
            return Some(p);
        }
    }
    None
}

macro_rules! scheduler_or_skip {
    () => {{
        match artifacts_dir() {
            None => {
                eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
                return;
            }
            Some(dir) => {
                let service = ExecutorService::spawn(&dir).expect("spawn executor service");
                let handle = service.handle();
                (service, Scheduler::new(4, Some(handle)))
            }
        }
    }};
}

fn job(w: WorkloadKind, nb: u64, map: &str, backend: Backend) -> Job {
    Job {
        workload: w,
        nb,
        map: map.into(),
        backend,
        seed: 23,
    }
}

#[test]
fn edm_pjrt_matches_rust_and_reference() {
    let (_svc, sched) = scheduler_or_skip!();
    let nb = 8;
    let w = EdmWorkload::generate(nb, sched.rho_for(2), 23);
    let (want_count, want_sum) = w.reference();
    for map in ["bb", "lambda2", "enum2", "rb"] {
        let pjrt = sched
            .run(&job(WorkloadKind::Edm, nb, map, Backend::Pjrt))
            .expect(map);
        assert_eq!(pjrt.outputs[0].1 as u64, want_count, "map={map} count");
        let sum = pjrt.outputs[1].1;
        assert!(
            (sum - want_sum).abs() < 1e-3 * want_sum.abs().max(1.0),
            "map={map}: {sum} vs {want_sum}"
        );
        assert!(pjrt.tile_batches > 0, "pjrt path must batch tiles");
    }
}

#[test]
fn collision_pjrt_matches_reference() {
    let (_svc, sched) = scheduler_or_skip!();
    let nb = 8;
    let w = simplexmap::workloads::CollisionWorkload::generate(nb, sched.rho_for(2), 23);
    let want = w.reference() as f64;
    for map in ["bb", "lambda2"] {
        let r = sched
            .run(&job(WorkloadKind::Collision, nb, map, Backend::Pjrt))
            .expect(map);
        assert_eq!(r.outputs[0].1, want, "map={map}");
    }
}

#[test]
fn nbody_pjrt_matches_reference() {
    let (_svc, sched) = scheduler_or_skip!();
    let nb = 4;
    let w = NBodyWorkload::generate(nb, sched.rho_for(2), 23);
    let want = NBodyWorkload::checksum(&w.reference());
    let r = sched
        .run(&job(WorkloadKind::NBody, nb, "lambda2", Backend::Pjrt))
        .unwrap();
    let got = r.outputs[0].1;
    assert!(
        (got - want).abs() < 2e-3 * want,
        "pjrt nbody: {got} vs {want}"
    );
}

#[test]
fn triple_pjrt_matches_reference() {
    let (_svc, sched) = scheduler_or_skip!();
    let nb = 4;
    let w = TripleWorkload::generate(nb, sched.rho_for(3), 23);
    let want = w.reference();
    for map in ["bb", "lambda3"] {
        let r = sched
            .run(&job(WorkloadKind::Triple, nb, map, Backend::Pjrt))
            .expect(map);
        let got = r.outputs[0].1;
        assert!(
            (got - want).abs() < 1e-4 * want.abs().max(1.0),
            "map={map}: {got} vs {want}"
        );
    }
}

#[test]
fn pjrt_and_rust_backends_agree_at_scale() {
    let (_svc, sched) = scheduler_or_skip!();
    let nb = 16; // 256 points, 136 tiles — several batches
    let rust = sched
        .run(&job(WorkloadKind::Edm, nb, "lambda2", Backend::Parallel))
        .unwrap();
    let pjrt = sched
        .run(&job(WorkloadKind::Edm, nb, "lambda2", Backend::Pjrt))
        .unwrap();
    assert_eq!(rust.outputs[0].1, pjrt.outputs[0].1, "counts must agree");
    let (a, b) = (rust.outputs[1].1, pjrt.outputs[1].1);
    assert!((a - b).abs() < 1e-3 * a.abs().max(1.0), "{a} vs {b}");
    // Same map → same launch geometry regardless of backend.
    assert_eq!(rust.blocks_launched, pjrt.blocks_launched);
    assert_eq!(rust.blocks_mapped, pjrt.blocks_mapped);
}

#[test]
fn executor_service_survives_bad_requests() {
    let (_svc, sched) = scheduler_or_skip!();
    // A failing job (unknown artifact path is impossible here, so use
    // an unsupported workload/backend combo) must not poison the
    // service for subsequent jobs.
    let bad = sched.run(&job(WorkloadKind::Cellular, 8, "lambda2", Backend::Pjrt));
    assert!(bad.is_err());
    let good = sched.run(&job(WorkloadKind::Edm, 8, "lambda2", Backend::Pjrt));
    assert!(good.is_ok());
}
