//! Property tests for the bounded job queue (`coordinator/queue.rs`)
//! on the shared `util::proptest` harness: random job bursts against
//! random (workers, capacity) configurations must
//!
//!  - never hold more than `capacity` pending jobs (the bound),
//!  - complete every *accepted* job exactly once,
//!  - reject overflow with the "queue full" backpressure error,
//!  - account accepted + rejected == submitted in the metrics.
//!
//! `server_concurrent.rs` covers the happy path through TCP; this file
//! covers the admission-control state machine itself.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use simplexmap::coordinator::{
    Backend, Job, JobQueue, QueueConfig, ScheduleError, Scheduler, WorkloadKind,
};
use simplexmap::util::prng::Xoshiro256;
use simplexmap::util::proptest::{check, Config, Prop};

fn job(seed: u64) -> Job {
    Job {
        workload: WorkloadKind::Edm,
        nb: 4,
        map: "lambda2".into(),
        backend: Backend::Parallel,
        seed,
    }
}

/// One random burst scenario.
#[derive(Clone, Debug)]
struct Burst {
    workers: usize,
    capacity: usize,
    jobs: usize,
}

fn gen_burst(rng: &mut Xoshiro256) -> Burst {
    Burst {
        workers: rng.gen_range(1, 4),
        capacity: rng.gen_range(1, 9),
        jobs: rng.gen_range(1, 33),
    }
}

/// Queue jobs keep their jobs tiny; a full default-sized case count
/// would spin up hundreds of worker pools for no extra coverage.
fn cases(n: usize) -> Config {
    Config {
        cases: n,
        ..Config::default()
    }
}

#[test]
fn random_bursts_respect_the_bound_and_complete_exactly_once() {
    check("queue-burst", &cases(40), gen_burst, |b| {
        let sched = Arc::new(Scheduler::new(2, None));
        let q = JobQueue::start(
            Arc::clone(&sched),
            QueueConfig {
                workers: b.workers,
                capacity: b.capacity,
            },
        );
        let mut receivers = Vec::new();
        let mut rejected = 0u64;
        for i in 0..b.jobs {
            // The pending-set bound must hold at every instant, not
            // just at the end: sample the gauge while submitting.
            if q.depth() > b.capacity as u64 {
                return Prop::Fail(format!("depth {} > capacity {}", q.depth(), b.capacity));
            }
            match q.submit(job(i as u64)) {
                Ok(rx) => receivers.push(rx),
                Err(ScheduleError::QueueFull(cap)) => {
                    if cap != b.capacity {
                        return Prop::Fail(format!("reported cap {cap} != {}", b.capacity));
                    }
                    rejected += 1;
                }
                Err(e) => return Prop::Fail(format!("unexpected error: {e}")),
            }
        }
        let accepted = receivers.len() as u64;
        if accepted + rejected != b.jobs as u64 {
            return Prop::Fail("accepted + rejected != submitted".into());
        }
        // Every accepted job resolves with a result (exactly one per
        // receiver — the reply channel is single-shot by construction).
        for rx in receivers {
            match rx.recv() {
                Ok(Ok(r)) => {
                    if r.outputs[0].0 != "neighbour_count" {
                        return Prop::Fail("wrong output key".into());
                    }
                }
                other => return Prop::Fail(format!("accepted job failed: {other:?}")),
            }
        }
        // Exactly-once execution: the scheduler ran each accepted job
        // one time, and the gauges settle back to empty.
        let m = &sched.metrics;
        if m.jobs_completed.load(Ordering::Relaxed) != accepted {
            return Prop::Fail(format!(
                "jobs_completed {} != accepted {accepted}",
                m.jobs_completed.load(Ordering::Relaxed)
            ));
        }
        if m.jobs_queued.load(Ordering::Relaxed) != accepted {
            return Prop::Fail("jobs_queued != accepted".into());
        }
        if m.queue_rejected.load(Ordering::Relaxed) != rejected {
            return Prop::Fail("queue_rejected metric disagrees".into());
        }
        Prop::from_bool(q.depth() == 0, "queue drained to depth 0")
    });
}

#[test]
fn rejections_report_queue_full_with_capacity() {
    // Saturate with no chance to drain meaningfully: tiny capacity,
    // instant submissions — every rejection must carry the canonical
    // backpressure message the server forwards to clients.
    let sched = Arc::new(Scheduler::new(1, None));
    let q = JobQueue::start(
        Arc::clone(&sched),
        QueueConfig {
            workers: 1,
            capacity: 1,
        },
    );
    let mut saw_rejection = false;
    let mut receivers = Vec::new();
    for i in 0..128u64 {
        match q.submit(job(i)) {
            Ok(rx) => receivers.push(rx),
            Err(e) => {
                saw_rejection = true;
                assert!(
                    matches!(e, ScheduleError::QueueFull(1)),
                    "wrong error: {e:?}"
                );
                assert!(e.to_string().contains("queue full"), "{e}");
            }
        }
    }
    assert!(saw_rejection, "128 instant submits vs capacity 1");
    for rx in receivers {
        rx.recv().unwrap().expect("accepted jobs still complete");
    }
}

#[test]
fn burst_of_mixed_workloads_drains_without_loss() {
    // Heterogeneous jobs (different workloads, dimensions and domains)
    // through one queue: everything accepted completes.
    let sched = Arc::new(Scheduler::new(2, None));
    let q = JobQueue::start(
        Arc::clone(&sched),
        QueueConfig {
            workers: 3,
            capacity: 64,
        },
    );
    let jobs = [
        (WorkloadKind::Edm, 4u64, "lambda2"),
        (WorkloadKind::Triple, 4, "lambda3"),
        (WorkloadKind::KTuple(4), 3, "bb"),
        (WorkloadKind::GasketCA, 4, "lambda-gasket"),
        (WorkloadKind::Cellular, 8, "rb"),
    ];
    let receivers: Vec<_> = jobs
        .iter()
        .map(|&(w, nb, map)| {
            q.submit(Job {
                workload: w,
                nb,
                map: map.into(),
                backend: Backend::Parallel,
                seed: 5,
            })
            .unwrap()
        })
        .collect();
    for (rx, (w, ..)) in receivers.into_iter().zip(jobs) {
        let reply = rx.recv().unwrap();
        let r = reply.unwrap_or_else(|e| panic!("{}: {e}", w.name()));
        assert_eq!(r.job.workload, w);
    }
    assert_eq!(sched.metrics.jobs_completed.load(Ordering::Relaxed), 5);
    assert_eq!(q.depth(), 0);
}
