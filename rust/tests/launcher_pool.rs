//! Property tests for the launch engine's chunk-cursor worker pool
//! (`grid/launcher.rs`) on the shared `util::proptest` harness: random
//! (workers, chunk_blocks, map, size) scenarios must
//!
//!  - issue every global block index exactly once (the cursor never
//!    skips a chunk and never double-issues one),
//!  - keep lane indices inside `workers()`,
//!  - have the per-lane tallies sum to the launch totals (the
//!    mutex-free merge loses nothing),
//!  - match the Serial backend's accounting bit for bit (all eight
//!    fields — the single-lane sweep is the oracle).
//!
//! `grid/launcher.rs` unit tests pin the named regressions (lane
//! starvation, backend agreement on specific maps); this file drives
//! the same invariants through ~1000 randomized launches.

use std::sync::atomic::{AtomicU64, Ordering};

use simplexmap::grid::{BackendKind, BlockShape, LaunchConfig, Launcher};
use simplexmap::maps::{adapt, BoundingBox2, Lambda2Map, MThreadMap, RiesMap};
use simplexmap::util::prng::Xoshiro256;
use simplexmap::util::proptest::{check, Config, Prop};

/// One random launch scenario.
#[derive(Clone, Debug)]
struct Scenario {
    workers: usize,
    chunk_blocks: usize,
    nb: u64,
    map: usize,
}

fn gen_scenario(rng: &mut Xoshiro256) -> Scenario {
    Scenario {
        workers: rng.gen_range(1, 9),
        // Deliberately tiny chunks too (1 block) to maximize cursor
        // contention, and oversized ones to hit the total/workers cap.
        chunk_blocks: rng.gen_range(1, 300),
        nb: [4u64, 8, 16][rng.gen_range(0, 3)],
        map: rng.gen_range(0, 3),
    }
}

fn make_map(which: usize) -> Box<dyn MThreadMap> {
    match which {
        0 => Box::new(adapt(Lambda2Map)),
        1 => Box::new(adapt(BoundingBox2)),
        _ => Box::new(adapt(RiesMap)),
    }
}

fn config(s: &Scenario, backend: BackendKind) -> LaunchConfig {
    let mut cfg = LaunchConfig::new(BlockShape::new(2, 2));
    cfg.launch_latency = std::time::Duration::ZERO;
    cfg.chunk_blocks = s.chunk_blocks;
    cfg.backend = backend;
    cfg
}

#[test]
fn random_launches_issue_every_block_exactly_once_with_exact_lane_sums() {
    check(
        "pool-chunk-cursor",
        &Config::default(),
        gen_scenario,
        |s| {
            let map = make_map(s.map);
            let nb = s.nb;
            // All three maps are injective into the data triangle, so a
            // per-data-block counter detects both skipped and
            // double-issued chunks.
            let seen: Vec<AtomicU64> = (0..nb * nb).map(|_| AtomicU64::new(0)).collect();
            let lane_mapped: Vec<AtomicU64> =
                (0..s.workers).map(|_| AtomicU64::new(0)).collect();
            let lane_pred: Vec<AtomicU64> =
                (0..s.workers).map(|_| AtomicU64::new(0)).collect();
            let l = Launcher::with_workers(s.workers, config(s, BackendKind::Parallel));
            let stats = l.launch(map.as_ref(), nb, |lane, b| {
                if lane >= s.workers {
                    // Panicking in a lane aborts the test with a join
                    // error — good enough for a property violation.
                    panic!("lane {lane} out of range (workers {})", s.workers);
                }
                seen[(b.data[1] * nb + b.data[0]) as usize].fetch_add(1, Ordering::Relaxed);
                lane_mapped[lane].fetch_add(1, Ordering::Relaxed);
                let p = u64::from(b.data[0] == b.data[1]);
                lane_pred[lane].fetch_add(p, Ordering::Relaxed);
                p
            });

            let mut mapped_total = 0u64;
            for (i, c) in seen.iter().enumerate() {
                let c = c.load(Ordering::Relaxed);
                if c > 1 {
                    return Prop::Fail(format!("data block {i} issued {c} times"));
                }
                mapped_total += c;
            }
            if mapped_total != stats.blocks_mapped {
                return Prop::Fail(format!(
                    "kernel saw {mapped_total} blocks, stats claim {}",
                    stats.blocks_mapped
                ));
            }
            let lane_sum: u64 = lane_mapped.iter().map(|c| c.load(Ordering::Relaxed)).sum();
            if lane_sum != stats.blocks_mapped {
                return Prop::Fail(format!(
                    "per-lane mapped sum {lane_sum} != total {}",
                    stats.blocks_mapped
                ));
            }
            let pred_sum: u64 = lane_pred.iter().map(|c| c.load(Ordering::Relaxed)).sum();
            if pred_sum != stats.threads_predicated_off {
                return Prop::Fail(format!(
                    "per-lane predication sum {pred_sum} != total {}",
                    stats.threads_predicated_off
                ));
            }

            // The single-lane Serial sweep is the accounting oracle.
            let oracle = Launcher::with_workers(1, config(s, BackendKind::Serial)).launch(
                map.as_ref(),
                nb,
                |_lane, b| u64::from(b.data[0] == b.data[1]),
            );
            Prop::from_bool(
                oracle.accounting() == stats.accounting(),
                &format!(
                    "accounting diverged: serial {:?} vs parallel {:?}",
                    oracle.accounting(),
                    stats.accounting()
                ),
            )
        },
    );
}

#[test]
fn single_block_and_single_worker_degenerate_cases() {
    // The smallest launches the cursor can see: one chunk, one lane.
    for (workers, chunk) in [(1usize, 1usize), (8, 1), (1, 4096)] {
        let s = Scenario {
            workers,
            chunk_blocks: chunk,
            nb: 4,
            map: 0,
        };
        let calls = AtomicU64::new(0);
        let l = Launcher::with_workers(s.workers, config(&s, BackendKind::Parallel));
        let stats = l.launch(make_map(0).as_ref(), s.nb, |_lane, _b| {
            calls.fetch_add(1, Ordering::Relaxed);
            0
        });
        assert_eq!(calls.load(Ordering::Relaxed), stats.blocks_mapped);
        assert_eq!(stats.blocks_mapped, 4 * (4 + 1) / 2, "λ2 covers T(4)");
    }
}
