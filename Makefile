# simplexmap — build/test/bench driver.
#
# `make test` is the tier-1 gate. `make artifacts` produces the AOT
# Pallas/HLO artifacts + JAX goldens the PJRT-backed tests consume;
# note that *executing* those artifacts from Rust additionally needs
# the real `xla` crate in place of runtime/xla_stub.rs (see DESIGN.md
# §Substitutions) — without it the artifact-dependent suites skip.

.PHONY: test build bench bench-export lint examples artifacts python-test clean

build:
	cd rust && cargo build --release

test:
	cd rust && cargo build --release && cargo test -q

lint:
	cd rust && cargo clippy --all-targets -- -D warnings
	cd rust && cargo run --release --bin simplexlint

bench:
	cd rust && cargo bench

# Offline perf snapshot: run the hot-path benches quickly and append
# their JSON lines to BENCH_local.json at the repo root — the file
# `simplexmap obs bench-trajectory` (and benchkit compare) consumes.
bench-export:
	cd rust && SIMPLEXMAP_BENCH_SECS=0.3 \
		SIMPLEXMAP_BENCH_JSON=$(CURDIR)/BENCH_local.json \
		cargo bench --bench map2_throughput --bench workload_e2e

examples:
	cd rust && cargo build --release --benches --examples

artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts

python-test:
	python -m pytest python/tests -q

clean:
	cd rust && cargo clean
	rm -rf artifacts
