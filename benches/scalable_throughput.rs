//! E16 — λ_S scalable-map throughput: the integer-Newton
//! rank-rearrangement arithmetic against the family it extends (λ2/λ3
//! at their pow2-only sizes, the enumeration maps it shares rank order
//! with, BB's predicate) — plus the same sweep at a non-power-of-two
//! size, which only λ_S, ENUM and BB can run at all.
//!
//! Run: `cargo bench --bench scalable_throughput`
//! (`SIMPLEXMAP_BENCH_NB` overrides the pow2 size; the JSON trajectory
//! lands wherever `SIMPLEXMAP_BENCH_JSON` points.)

use simplexmap::maps::lambda2::lambda2_inclusive;
use simplexmap::maps::lambda_scalable::{lambda_s2, lambda_s3, scalable_width};
use simplexmap::maps::{Lambda3Map, LambdaScalable3, ThreadMap};
use simplexmap::util::benchkit::{black_box, section, Bencher};
use simplexmap::util::isqrt::{isqrt_u64, triangular_root};

fn main() {
    let nb: u64 = std::env::var("SIMPLEXMAP_BENCH_NB")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2048);
    // The λ2/λ3 comparison rows are only defined at powers of two; the
    // non-pow2 sections below pick their own awkward size from nb.
    assert!(nb.is_power_of_two() && nb >= 64, "SIMPLEXMAP_BENCH_NB must be 2^k ≥ 64");

    section(&format!("E16: λ_S m=2 block-rearrangement throughput, nb = {nb}"));
    let mut b = Bencher::default();
    let useful = nb * (nb + 1) / 2;
    let w2 = scalable_width(nb);
    let h2 = useful / w2;

    // λ_S over its exact half-width grid (one integer isqrt per block).
    b.bench("lambda-s m=2 (integer Newton rank)", useful, || {
        let mut acc = 0u64;
        for y in 0..h2 {
            for x in 0..w2 {
                let (c, r) = lambda_s2(black_box(y * w2 + x));
                acc = acc.wrapping_add(c + r);
            }
        }
        black_box(acc);
    });

    // λ2 at the same (power-of-two) size: the cheaper clz+shift per
    // block that λ_S trades for arbitrary-nb support.
    b.bench("lambda2 (clz + shift, pow2 only)", useful, || {
        let mut acc = 0u64;
        for y in 0..=nb {
            for x in 0..nb / 2 {
                let (c, r) = lambda2_inclusive(nb, black_box(x), black_box(y));
                acc = acc.wrapping_add(c + r);
            }
        }
        black_box(acc);
    });

    // BB baseline: identity + predicate over the full square.
    b.bench("bb2 (identity + predicate)", useful, || {
        let mut acc = 0u64;
        for y in 0..nb {
            for x in 0..nb {
                if x <= y {
                    acc = acc.wrapping_add(black_box(x + y));
                }
            }
        }
        black_box(acc);
    });
    b.print_speedups("E16 m=2 summary");

    // Non-power-of-two: λ2 cannot run here at all — λ_S vs BB only.
    let odd = nb + 1 + nb / 2; // deliberately awkward (e.g. 3073)
    section(&format!("E16: non-pow2 scalability, nb = {odd}"));
    let mut b = Bencher::default();
    let useful_odd = odd * (odd + 1) / 2;
    let w_odd = scalable_width(odd);
    let h_odd = useful_odd / w_odd;
    b.bench("lambda-s m=2 (non-pow2 exact)", useful_odd, || {
        let mut acc = 0u64;
        for y in 0..h_odd {
            for x in 0..w_odd {
                let (c, r) = lambda_s2(black_box(y * w_odd + x));
                acc = acc.wrapping_add(c + r);
            }
        }
        black_box(acc);
    });
    b.bench("bb2 (non-pow2 predicate)", useful_odd, || {
        let mut acc = 0u64;
        for y in 0..odd {
            for x in 0..odd {
                if x <= y {
                    acc = acc.wrapping_add(black_box(x + y));
                }
            }
        }
        black_box(acc);
    });
    b.print_speedups("E16 non-pow2 summary");

    // m = 3: λ_S tetrahedral extension vs λ3 through the map interface.
    let nb3 = (nb / 16).max(4);
    section(&format!("E16: m=3 tetrahedral extension, nb = {nb3}"));
    let mut b = Bencher::default();
    let useful3 = nb3 * (nb3 + 1) * (nb3 + 2) / 6;
    b.bench("lambda-s m=3 (two integer roots)", useful3, || {
        let mut acc = 0u64;
        for k in 0..useful3 {
            let (x, y, z) = lambda_s3(black_box(k));
            acc = acc.wrapping_add(x + y + z);
        }
        black_box(acc);
    });
    let l3 = Lambda3Map;
    if l3.supports(nb3) {
        b.bench("lambda3 (map interface, pow2 only)", useful3, || {
            let mut acc = 0u64;
            for pass in 0..l3.passes(nb3) {
                for w in l3.grid(nb3, pass).iter() {
                    if let Some(d) = l3.map_block(nb3, pass, black_box(w)) {
                        acc = acc.wrapping_add(d[0] + d[1] + d[2]);
                    }
                }
            }
            black_box(acc);
        });
    }
    let ls3 = LambdaScalable3;
    b.bench("lambda-s m=3 (map interface)", useful3, || {
        let mut acc = 0u64;
        for w in ls3.grid(nb3, 0).iter() {
            if let Some(d) = ls3.map_block(nb3, 0, black_box(w)) {
                acc = acc.wrapping_add(d[0] + d[1] + d[2]);
            }
        }
        black_box(acc);
    });
    b.print_speedups("E16 m=3 summary");

    // The root primitive itself: integer Newton isqrt vs f64 sqrt+fixup
    // (the cost the precision fix buys at, measured).
    section("E16: root primitive microbench");
    let mut b = Bencher::default();
    let n_roots = 1u64 << 16;
    b.bench("isqrt_u64 (integer Newton)", n_roots, || {
        let mut acc = 0u64;
        for i in 0..n_roots {
            acc = acc.wrapping_add(isqrt_u64(black_box(i * 48_271 + 11)));
        }
        black_box(acc);
    });
    b.bench("triangular_root (isqrt-based)", n_roots, || {
        let mut acc = 0u64;
        for i in 0..n_roots {
            acc = acc.wrapping_add(triangular_root(black_box(i * 48_271 + 11)));
        }
        black_box(acc);
    });
    b.bench("f64 sqrt + cast (unfixed)", n_roots, || {
        let mut acc = 0u64;
        for i in 0..n_roots {
            let k = black_box(i * 48_271 + 11);
            acc = acc.wrapping_add((((8.0 * k as f64 + 1.0).sqrt() - 1.0) * 0.5) as u64);
        }
        black_box(acc);
    });
    b.print_speedups("E16 root summary");
}
