//! E3 — raw block-map arithmetic throughput for the 2-simplex (the
//! paper's O(1)-beats-sqrt claim, eq. 13-15 vs the enumeration maps).
//!
//! Measures blocks mapped per second over a full grid sweep for every
//! registered map: BB identity+predicate, λ2 (clz+shift), ENUM2
//! (sqrt), RB (compare+mirror), Avril (f64 sqrt, thread-space) and the
//! per-pass Ries map. Custom harness (vendor set has no criterion).

use simplexmap::maps::{
    avril::avril_map_f64, lambda2::lambda2_inclusive, rectangular_box::rb_map, ThreadMap,
};
use simplexmap::util::benchkit::{black_box, section, Bencher};

fn main() {
    let nb: u64 = std::env::var("SIMPLEXMAP_BENCH_NB")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2048);
    section(&format!("E3: m=2 block-map throughput, nb = {nb}"));
    let mut b = Bencher::default();

    // Every bench maps the same number of *useful* blocks so the
    // throughput numbers are directly comparable.
    let useful = (nb * (nb + 1) / 2) as u64;

    // BB: identity + predicate over the full square (the baseline pays
    // for the dead half too — that's the point).
    b.bench("bb2 (identity + predicate, full grid)", useful, || {
        let mut acc = 0u64;
        for y in 0..nb {
            for x in 0..nb {
                if x <= y {
                    acc = acc.wrapping_add(black_box(x + y));
                }
            }
        }
        black_box(acc);
    });

    // λ2: the paper's map (eq. 13) over its exact grid.
    b.bench("lambda2 (clz + shift, eq. 13)", useful, || {
        let mut acc = 0u64;
        for y in 0..=nb {
            for x in 0..nb / 2 {
                let (c, r) = lambda2_inclusive(nb, black_box(x), black_box(y));
                acc = acc.wrapping_add(c + r);
            }
        }
        black_box(acc);
    });

    // ENUM2: triangular root per block (HPCC'14 baseline).
    b.bench("enum2 (sqrt root per block)", useful, || {
        let mut acc = 0u64;
        for k in 0..useful {
            let r = simplexmap::maps::enumeration::triangular_root(black_box(k));
            let c = k - r * (r + 1) / 2;
            acc = acc.wrapping_add(c + r);
        }
        black_box(acc);
    });

    // RB: fold map.
    b.bench("rb (fold, Jung & O'Leary)", useful, || {
        let mut acc = 0u64;
        for y in 0..=nb {
            for x in 0..nb / 2 {
                let (c, r) = rb_map(nb, black_box(x), black_box(y));
                acc = acc.wrapping_add(c + r);
            }
        }
        black_box(acc);
    });

    // Avril: thread-space f64 sqrt map (strict pairs only).
    let strict = nb * (nb - 1) / 2;
    b.bench("avril (f64 sqrt, thread-space)", strict, || {
        let mut acc = 0u64;
        for k in 0..strict {
            let (a, bb_) = avril_map_f64(black_box(k), nb);
            acc = acc.wrapping_add(a + bb_);
        }
        black_box(acc);
    });

    // Ries: same arithmetic as λ2 levels but via the multi-pass
    // interface (per-block cost only; launch overhead is E12).
    let ries = simplexmap::maps::RiesMap;
    b.bench("ries (per-block, all passes)", useful, || {
        let mut acc = 0u64;
        for pass in 0..ries.passes(nb) {
            let g = ries.grid(nb, pass);
            for w in g.iter() {
                if let Some(d) = ries.map_block(nb, pass, black_box(w)) {
                    acc = acc.wrapping_add(d[0] + d[1]);
                }
            }
        }
        black_box(acc);
    });

    b.print_speedups("E3 summary");
}
