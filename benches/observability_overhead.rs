//! E18 — observability overhead: the instrumentation added for spans,
//! histograms and per-lane profiling must stay out of the hot path.
//!
//! The gated claim is about the *disabled* path (profiling off, spans
//! off — the default): its only residue in the sweep is one untaken
//! branch per work chunk. That residue is strictly cheaper than the
//! *enabled* path, whose per-chunk cost is two monotonic clock reads
//! plus three counter adds — so gating `enabled / disabled ≤
//! SIMPLEXMAP_OBS_OVERHEAD_MAX` (default 1.05, i.e. < 5%) bounds the
//! disabled-path overhead a fortiori. The disabled sweep is measured
//! twice (before and after the enabled one) and the faster run is the
//! denominator, so drift penalizes rather than masks a regression.
//! Set the env var to 0 to measure without gating.
//!
//! The second half micro-benches the primitives themselves: histogram
//! record/quantile and span start/finish on both disabled and enabled
//! recorders.

use simplexmap::coordinator::SpanRecorder;
use simplexmap::grid::{BackendKind, BlockShape, LaunchConfig, Launcher};
use simplexmap::maps::{adapt, Lambda2Map, ThreadMap};
use simplexmap::util::benchkit::{black_box, section, Bencher};
use simplexmap::util::histogram::Histogram;

const NB: u64 = 2048;
const WORKERS: usize = 4;

fn launcher(profile_lanes: bool) -> Launcher {
    let mut cfg = LaunchConfig::new(BlockShape::new(1, 2));
    cfg.launch_latency = std::time::Duration::ZERO;
    cfg.backend = BackendKind::Parallel;
    cfg.profile_lanes = profile_lanes;
    Launcher::with_workers(WORKERS, cfg)
}

fn bench_sweep(b: &mut Bencher, name: &str, profile_lanes: bool) -> f64 {
    let map = adapt(Lambda2Map);
    let l = launcher(profile_lanes);
    let blocks = Lambda2Map.parallel_volume(NB) as u64;
    let r = b.bench(name, blocks, || {
        let stats = l.launch(&map, NB, |_lane, b| black_box(b.data[0]) & 1);
        black_box(stats.blocks_mapped);
    });
    r.secs_per_iter.p50
}

fn main() {
    section("E18: map_block sweep with lane profiling off/on (λ2, nb=2048)");
    let mut b = Bencher::default();
    let off1 = bench_sweep(&mut b, "sweep profile=off (1st)", false);
    let on = bench_sweep(&mut b, "sweep profile=on", true);
    let off2 = bench_sweep(&mut b, "sweep profile=off (2nd)", false);
    b.print_speedups("E18 sweep");

    // One profiled launch to show what the enabled path buys.
    let map = adapt(Lambda2Map);
    let stats = launcher(true).launch(&map, NB, |_lane, b| black_box(b.data[0]) & 1);
    println!("\nper-lane profile of one launch:");
    for lane in &stats.lanes {
        println!(
            "  lane {}: busy {:>9} ns  chunks {:>3}  blocks {:>8}",
            lane.lane, lane.busy_ns, lane.chunks_pulled, lane.blocks_processed
        );
    }
    if let Some(r) = stats.lane_imbalance() {
        println!("  lane imbalance (max/mean busy): {r:.3}x");
    }

    section("E18: observability primitives");
    let mut b = Bencher::default();
    let hist = Histogram::new();
    b.bench("histogram record_ns", 1_000_000, || {
        for i in 0..1_000_000u64 {
            hist.record_ns(black_box(i.wrapping_mul(2654435761) % 1_000_000_000));
        }
    });
    b.bench("histogram quantile walk (4 quantiles)", 1, || {
        black_box(hist.summary_quantiles_secs());
    });

    let disabled = SpanRecorder::new(1024);
    b.bench("span start+finish (disabled)", 1_000_000, || {
        for _ in 0..1_000_000u32 {
            let s = disabled.start("bench", "noop", 0);
            disabled.finish(s);
        }
    });
    let enabled = SpanRecorder::new(1024);
    enabled.set_enabled(true);
    b.bench("span start+finish (enabled, ring 1024)", 100_000, || {
        for _ in 0..100_000u32 {
            let s = enabled.start("bench", "noop", 0);
            enabled.finish(s);
        }
    });

    let ratio = on / off1.min(off2);
    let max: f64 = std::env::var("SIMPLEXMAP_OBS_OVERHEAD_MAX")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.05);
    println!("\nprofiled/unprofiled sweep ratio: {ratio:.4}x (ceiling {max}x)");
    if max > 0.0 && ratio > max {
        eprintln!("observability_overhead: FAIL — {ratio:.4}x > allowed {max}x");
        std::process::exit(1);
    }
}
