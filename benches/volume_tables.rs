//! E1 / E4 / E8 / E9 — regenerate every closed-form table of the paper
//! (eqs. 2-4, 17-19, 28-29 and the §III.D search) and time the exact
//! arithmetic so regressions in the u128 volume kernels are caught.

use simplexmap::analysis;
use simplexmap::simplex::recursive_set::recursive_volume_half;
use simplexmap::simplex::volume::simplex_volume;
use simplexmap::util::benchkit::{black_box, section, Bencher};

fn main() {
    section("E1: bounding-box waste (eq. 4)");
    println!("{}", analysis::report_volumes(4096, 8));

    section("E4: arity-3 set → 1/5 extra volume (eq. 19)");
    println!("{}", analysis::report_arity3(14));

    section("E8: r=1/2 β=2 blow-up (eq. 29)");
    println!("{}", analysis::report_general(8));

    section("E9: §III.D (r, β) search");
    println!(
        "{}",
        analysis::report_search(4, 9, &[2.0, 4.0, 8.0, 16.0, 32.0], 1 << 40)
    );

    section("timing: exact volume kernels");
    let mut b = Bencher::default();
    b.bench("simplex_volume m=2..8, n=2^12", 7, || {
        // n=2^12 keeps C(n+7, 8) inside u128 (2^20 would overflow).
        for m in 2..=8 {
            black_box(simplex_volume(1 << 12, m));
        }
    });
    b.bench("recursive_volume_half n=2^40 m=3", 1, || {
        black_box(recursive_volume_half(1 << 40, 3, 2));
    });
    b.bench("gensearch m=5 five betas", 5, || {
        black_box(simplexmap::gensearch::search(
            (5, 5),
            &[2.0, 4.0, 8.0, 16.0, 32.0],
            1 << 40,
        ));
    });
}
