//! E5 / E12 — launch-count economics: single-pass λ maps vs the
//! multi-pass related work, under the simulated per-launch latency and
//! the 32-concurrent-kernel cap (§III.B's argument, eq. 20).

use std::time::Duration;

use simplexmap::grid::{BlockShape, LaunchConfig, Launcher};
use simplexmap::maps::{Lambda2Map, Lambda3Map, Lambda3RecMap, RiesMap, ThreadMap};
use simplexmap::util::benchkit::{black_box, section, Bencher};

fn launcher(m: u32, latency_us: u64) -> Launcher {
    let mut cfg = LaunchConfig::new(BlockShape::new(4, m));
    cfg.launch_latency = Duration::from_micros(latency_us);
    cfg.max_concurrent_launches = 32;
    Launcher::with_workers(
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
        cfg,
    )
}

fn main() {
    section("E12: λ2 single pass vs Ries O(log n) passes (5µs launch latency)");
    let mut b = Bencher::default();
    let nb2 = 1024;
    for (name, map) in [
        ("lambda2 (1 pass)", &Lambda2Map as &dyn ThreadMap),
        ("ries (log2 n + 1 passes)", &RiesMap),
    ] {
        let l = launcher(2, 5);
        b.bench(name, map.parallel_volume(nb2) as u64, || {
            let stats = l.launch(map, nb2, |_b| 0);
            black_box(stats.blocks_mapped);
        });
    }
    b.print_speedups("E12");

    section("E5: λ3 single pass vs λ3-rec O(3^log n) launches (cap 32)");
    let mut b = Bencher::default();
    let nb3 = 64;
    for (name, map) in [
        ("lambda3 (1 pass)", &Lambda3Map as &dyn ThreadMap),
        ("lambda3-rec (365 launches at nb=64)", &Lambda3RecMap),
    ] {
        let l = launcher(3, 5);
        b.bench(name, map.parallel_volume(nb3) as u64, || {
            let stats = l.launch(map, nb3, |_b| 0);
            black_box(stats.blocks_mapped);
        });
    }
    b.print_speedups("E5");

    // Pass-count table (the eq. 20 numbers behind the wall times).
    println!("\npasses: lambda2={} ries={} lambda3={} lambda3-rec={}",
        Lambda2Map.passes(nb2),
        RiesMap.passes(nb2),
        Lambda3Map.passes(nb3),
        Lambda3RecMap.passes(nb3),
    );
}
