//! E5 / E12 — launch-count economics: single-pass λ maps vs the
//! multi-pass related work, under the simulated per-launch latency and
//! the 32-concurrent-kernel cap (§III.B's argument, eq. 20).
//!
//! This bench is the one place that *wants* the launch-latency model
//! to cost real wall time, so it opts into
//! `LaunchConfig::simulate_latency` (the engine runs accounting-only).

use std::time::Duration;

use simplexmap::grid::{BlockShape, LaunchConfig, Launcher};
use simplexmap::maps::{map2_by_name, map3_by_name, FixedAdapter, ThreadMap};
use simplexmap::util::benchkit::{black_box, section, Bencher};

fn launcher(m: u32, latency_us: u64) -> Launcher {
    let mut cfg = LaunchConfig::new(BlockShape::new(4, m));
    cfg.launch_latency = Duration::from_micros(latency_us);
    cfg.max_concurrent_launches = 32;
    cfg.simulate_latency = true;
    Launcher::with_workers(
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
        cfg,
    )
}

fn bench_map(b: &mut Bencher, l: &Launcher, name: &str, fixed: Box<dyn ThreadMap>, nb: u64) {
    let volume = fixed.parallel_volume(nb) as u64;
    let map = FixedAdapter::new(fixed);
    b.bench(name, volume, || {
        let stats = l.launch(&map, nb, |_lane, _b| 0);
        black_box(stats.blocks_mapped);
    });
}

fn main() {
    section("E12: λ2 single pass vs Ries O(log n) passes (5µs launch latency)");
    let mut b = Bencher::default();
    let nb2 = 1024;
    for (name, map_name) in [
        ("lambda2 (1 pass)", "lambda2"),
        ("ries (log2 n + 1 passes)", "ries"),
    ] {
        let l = launcher(2, 5);
        bench_map(&mut b, &l, name, map2_by_name(map_name).unwrap(), nb2);
    }
    b.print_speedups("E12");

    section("E5: λ3 single pass vs λ3-rec O(3^log n) launches (cap 32)");
    let mut b = Bencher::default();
    let nb3 = 64;
    for (name, map_name) in [
        ("lambda3 (1 pass)", "lambda3"),
        ("lambda3-rec (365 launches at nb=64)", "lambda3-rec"),
    ] {
        let l = launcher(3, 5);
        bench_map(&mut b, &l, name, map3_by_name(map_name).unwrap(), nb3);
    }
    b.print_speedups("E5");

    // Pass-count table (the eq. 20 numbers behind the wall times).
    println!(
        "\npasses: lambda2={} ries={} lambda3={} lambda3-rec={}",
        map2_by_name("lambda2").unwrap().passes(nb2),
        map2_by_name("ries").unwrap().passes(nb2),
        map3_by_name("lambda3").unwrap().passes(nb3),
        map3_by_name("lambda3-rec").unwrap().passes(nb3),
    );
}
