//! E13 — block-map arithmetic throughput for the general-m subsystem:
//! λ_m's combinatorial unranking (binary-searched binomials, §III.D
//! made executable) vs BB_m's predicate-discard over the full nb^m
//! orthotope. The interesting number is useful-blocks/s: BB_m touches
//! ≈ m! parallel blocks per useful one, so λ_m wins end to end even
//! though its per-block arithmetic is heavier.

use simplexmap::maps::{BoundingBoxM, LambdaMMap, MThreadMap};
use simplexmap::util::benchkit::{black_box, section, Bencher};

fn bench_map(b: &mut Bencher, label: &str, map: &dyn MThreadMap, nb: u64) {
    let useful = simplexmap::maps::domain_volume(nb, map.m()) as u64;
    b.bench(label, useful, || {
        let mut acc = 0u64;
        for pass in 0..map.passes(nb) {
            for w in map.grid(nb, pass).iter() {
                if let Some(d) = map.map_block(nb, pass, black_box(&w)) {
                    acc = acc.wrapping_add(d.sum());
                }
            }
        }
        black_box(acc);
    });
}

fn main() {
    let nb: u64 = std::env::var("SIMPLEXMAP_BENCH_NB")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(28);
    section(&format!(
        "E13: general-m block-map throughput, nb ≈ {nb} (first covered size ≥ nb)"
    ));
    let mut b = Bencher::default();

    // m=5's BB sweep is nb^5 blocks per iteration, so cap its size.
    for (m, beta, target) in [(4u32, 2u32, nb), (5, 32, nb.min(12))] {
        let lam = LambdaMMap::for_paper(m, beta);
        let native = lam
            .native_size(target)
            .expect("covered size within the horizon");
        bench_map(
            &mut b,
            &format!("lambda-m (m={m}, β={beta}, unranking) nb={native}"),
            &lam,
            native,
        );
        let bb = BoundingBoxM::new(m);
        bench_map(
            &mut b,
            &format!("bb (m={m}, identity + predicate) nb={native}"),
            &bb,
            native,
        );
    }

    b.print_speedups("E13 summary");
}
