//! E7 — block-map arithmetic throughput for the 3-simplex: λ3's
//! clz+fold (§III.C) vs BB's predicate-discard vs ENUM3's cube-root
//! inversion (the "several square and cubic roots" the paper's related
//! work pays).

use simplexmap::maps::lambda3::lambda3_full;
use simplexmap::maps::{Enum3Map, ThreadMap};
use simplexmap::util::benchkit::{black_box, section, Bencher};

fn main() {
    let nb: u64 = std::env::var("SIMPLEXMAP_BENCH_NB")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(256);
    section(&format!("E7: m=3 block-map throughput, nb = {nb}"));
    let mut b = Bencher::default();
    let useful = (nb * (nb + 1) * (nb + 2) / 6) as u64;

    // BB: identity + predicate over the full cube (pays ~6×).
    b.bench("bb3 (identity + predicate, full grid)", useful, || {
        let mut acc = 0u64;
        for z in 0..nb {
            for y in 0..nb {
                for x in 0..nb {
                    if x + y + z <= nb - 1 {
                        acc = acc.wrapping_add(black_box(x + y + z));
                    }
                }
            }
        }
        black_box(acc);
    });

    // λ3: clz + closed-form offsets + fold, over its 1.125× container.
    b.bench("lambda3 (clz + fold, §III.C)", useful, || {
        let mut acc = 0u64;
        let (gx, gy, gz) = (nb / 2, nb / 2, 3 * nb / 4 + 3);
        for z in 0..gz {
            for y in 0..gy {
                for x in 0..gx {
                    if let Some((a, bb_, c)) =
                        lambda3_full(nb, black_box(x), black_box(y), black_box(z))
                    {
                        acc = acc.wrapping_add(a + bb_ + c);
                    }
                }
            }
        }
        black_box(acc);
    });

    // ENUM3: tetrahedral + triangular root per block.
    let enum3 = Enum3Map;
    b.bench("enum3 (cbrt + sqrt roots per block)", useful, || {
        let mut acc = 0u64;
        let g = enum3.grid(nb, 0);
        for w in g.iter() {
            if let Some(d) = enum3.map_block(nb, 0, black_box(w)) {
                acc = acc.wrapping_add(d[0] + d[1] + d[2]);
            }
        }
        black_box(acc);
    });

    b.print_speedups("E7 summary");
}
