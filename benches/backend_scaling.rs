//! E17 — backend scaling: the Serial reference sweep vs the
//! chunk-cursor worker pool on the pure `map_block` workload (trivial
//! kernel, so the measured work is the map sweep itself — the λ2
//! inverse per block at nb = 4096, ~8.4M mapped blocks per iteration).
//!
//! This is the PR 6 acceptance bench: the pool must deliver at least
//! `SIMPLEXMAP_BACKEND_SCALING_MIN`× (default 2.0×) the Serial
//! throughput at 4 workers, or the process exits non-zero — the
//! lane-starvation bug this PR fixes made exactly this configuration
//! degenerate to ~1×. Set the env var to 0 to measure without gating
//! (e.g. on single-core runners).

use simplexmap::grid::{BackendKind, BlockShape, LaunchConfig, Launcher};
use simplexmap::maps::{adapt, Lambda2Map, ThreadMap};
use simplexmap::util::benchkit::{black_box, section, Bencher};

const NB: u64 = 4096;

fn launcher(backend: BackendKind, workers: usize) -> Launcher {
    let mut cfg = LaunchConfig::new(BlockShape::new(1, 2));
    cfg.launch_latency = std::time::Duration::ZERO;
    cfg.backend = backend;
    Launcher::with_workers(workers, cfg)
}

fn bench_backend(b: &mut Bencher, name: &str, backend: BackendKind, workers: usize) -> f64 {
    let map = adapt(Lambda2Map);
    let l = launcher(backend, workers);
    let blocks = Lambda2Map.parallel_volume(NB) as u64;
    let r = b.bench(name, blocks, || {
        let stats = l.launch(&map, NB, |_lane, b| black_box(b.data[0]) & 1);
        black_box(stats.blocks_mapped);
    });
    r.secs_per_iter.p50
}

fn main() {
    section("E17: map_block sweep, Serial vs Parallel backends (λ2, nb=4096)");
    let mut b = Bencher::default();
    let serial = bench_backend(&mut b, "serial (1 lane)", BackendKind::Serial, 1);
    let mut at4 = f64::NAN;
    for workers in [2usize, 4, 8] {
        let p = bench_backend(
            &mut b,
            &format!("parallel ({workers} workers)"),
            BackendKind::Parallel,
            workers,
        );
        if workers == 4 {
            at4 = p;
        }
    }
    b.print_speedups("E17");

    let speedup = serial / at4;
    let min: f64 = std::env::var("SIMPLEXMAP_BACKEND_SCALING_MIN")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2.0);
    println!("\nserial/parallel(4) wall-clock ratio: {speedup:.2}x (floor {min}x)");
    if min > 0.0 && speedup < min {
        eprintln!("backend_scaling: FAIL — {speedup:.2}x < required {min}x");
        std::process::exit(1);
    }
}
