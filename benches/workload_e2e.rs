//! E10 — end-to-end workload wall time under each map (rust backend:
//! measures the whole pipeline map→tiles→aggregate without PJRT call
//! overhead dominating; the PJRT flavour is examples/edm_end_to_end).
//!
//! The paper's prediction: identical tile work, so wall time scales
//! with parallel-space volume — λ2 ≈ ½ BB for m=2, λ3 ≈ ⅙ BB for m=3
//! *in the map phase*, converging to the tile-work ratio end-to-end.

use simplexmap::coordinator::{Backend, Job, Scheduler, WorkloadKind};
use simplexmap::util::benchkit::{section, Bencher};

fn bench_workload(
    b: &mut Bencher,
    sched: &Scheduler,
    workload: WorkloadKind,
    nb: u64,
    maps: &[&str],
    items: u64,
) {
    for map in maps {
        let job = Job {
            workload,
            nb,
            map: map.to_string(),
            backend: Backend::Parallel,
            seed: 42,
        };
        b.bench(&format!("{} nb={nb} map={map}", workload.name()), items, || {
            let r = sched.run(&job).expect("job");
            simplexmap::util::benchkit::black_box(r.outputs[0].1);
        });
    }
}

fn main() {
    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4);
    let sched = Scheduler::new(workers, None);

    section("E10a: EDM end-to-end (rust tiles)");
    let mut b = Bencher::default();
    let nb = 128;
    let n = nb * sched.rho_for(2) as u64;
    bench_workload(
        &mut b,
        &sched,
        WorkloadKind::Edm,
        nb,
        &["bb", "enum2", "lambda2", "rb"],
        n * (n - 1) / 2,
    );
    b.print_speedups("EDM");

    section("E10b: collision culling end-to-end");
    let mut b = Bencher::default();
    bench_workload(
        &mut b,
        &sched,
        WorkloadKind::Collision,
        nb,
        &["bb", "lambda2"],
        n * (n - 1) / 2,
    );
    b.print_speedups("collision");

    section("E10c: n-body end-to-end");
    let mut b = Bencher::default();
    let nb_n = 64;
    let n_n = nb_n * sched.rho_for(2) as u64;
    bench_workload(
        &mut b,
        &sched,
        WorkloadKind::NBody,
        nb_n,
        &["bb", "lambda2"],
        n_n * (n_n - 1),
    );
    b.print_speedups("nbody");

    section("E10d: triple interaction end-to-end (m=3)");
    let mut b = Bencher::default();
    let nb3 = 16;
    let n3 = nb3 * sched.rho_for(3) as u64;
    bench_workload(
        &mut b,
        &sched,
        WorkloadKind::Triple,
        nb3,
        &["bb", "enum3", "lambda3"],
        n3 * (n3 - 1) * (n3 - 2) / 6,
    );
    b.print_speedups("triple");

    section("E14: k-tuple end-to-end (m=4, unified engine)");
    let mut b = Bencher::default();
    let nb4 = 16;
    let n4 = nb4 * sched.rho_for(4) as u64;
    // C(n, 4) useful tuples.
    let tuples4 = n4 * (n4 - 1) * (n4 - 2) * (n4 - 3) / 24;
    bench_workload(
        &mut b,
        &sched,
        WorkloadKind::KTuple(4),
        nb4,
        &["bb", "lambda-m"],
        tuples4,
    );
    b.print_speedups("ktuple4");
}
