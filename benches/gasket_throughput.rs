//! E15 — fractal-domain throughput: the gasket block-space map λ_Δ
//! (O(log n) base-3 digit descent, zero filler) vs the gasket bounding
//! box (O(1) predicate, (4/3)^k filler blocks), as map arithmetic and
//! end to end under the gasket CA workload.
//!
//! The interesting number is useful-blocks/s: BB_Δ touches (4/3)^k
//! parallel blocks per useful one (≈5.6× at k = 6, unbounded in k), so
//! λ_Δ wins the sweep even though its per-block arithmetic is heavier —
//! the fractal counterpart of the λ_m-vs-BB_m story.

use simplexmap::coordinator::{Backend, Job, Scheduler, WorkloadKind};
use simplexmap::maps::{GasketBoundingBoxMap, GasketLambdaMap, MThreadMap};
use simplexmap::util::benchkit::{black_box, section, Bencher};

fn bench_map(b: &mut Bencher, label: &str, map: &dyn MThreadMap, nb: u64) {
    let useful = map.domain_volume(nb) as u64;
    b.bench(label, useful, || {
        let mut acc = 0u64;
        for pass in 0..map.passes(nb) {
            for w in map.grid(nb, pass).iter() {
                if let Some(d) = map.map_block(nb, pass, black_box(&w)) {
                    acc = acc.wrapping_add(d.sum());
                }
            }
        }
        black_box(acc);
    });
}

fn main() {
    let nb: u64 = std::env::var("SIMPLEXMAP_BENCH_NB")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(256);
    let k = nb.trailing_zeros();
    assert!(nb.is_power_of_two(), "gasket sizes are 2^k");

    section(&format!(
        "E15a: gasket block-map throughput, nb={nb} (k={k}, 3^k={} useful blocks)",
        3u64.pow(k)
    ));
    let mut b = Bencher::default();
    bench_map(
        &mut b,
        &format!("lambda-gasket (digit descent) nb={nb}"),
        &GasketLambdaMap,
        nb,
    );
    bench_map(
        &mut b,
        &format!("bb-gasket (identity + predicate) nb={nb}"),
        &GasketBoundingBoxMap,
        nb,
    );
    b.print_speedups("E15a summary");

    section("E15b: gasket CA end-to-end (rust tiles)");
    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4);
    let sched = Scheduler::new(workers, None);
    let nb_e2e = nb.min(64);
    let rho = sched.rho.rho_gasket as u64;
    let cells = 3u64.pow(nb_e2e.trailing_zeros() + rho.trailing_zeros());
    let mut b = Bencher::default();
    for map in ["bb-gasket", "lambda-gasket", "bb", "lambda2"] {
        let job = Job {
            workload: WorkloadKind::GasketCA,
            nb: nb_e2e,
            map: map.to_string(),
            backend: Backend::Parallel,
            seed: 42,
        };
        b.bench(&format!("gasket nb={nb_e2e} map={map}"), cells, || {
            let r = sched.run(&job).expect("job");
            black_box(r.outputs[3].1);
        });
    }
    b.print_speedups("E15b summary");
}
