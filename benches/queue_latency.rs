//! E19 — queue latency: enqueue→dequeue wait percentiles under a full
//! burst, plus the end-to-end submit→drain throughput of the bounded
//! job queue. These rows feed the gated bench-regression set so the
//! serving tier's admission path cannot silently regress.
//!
//! Each burst uses a fresh scheduler + queue (fresh metrics), submits
//! `BURST` small jobs back-to-back and drains them; the queue_wait
//! p50/p99 from that burst's metrics snapshot are one sample each. The
//! exported rows use `items_per_iter = 1`, so `throughput_per_sec =
//! 1 / latency` and the regression gate's throughput-ratio check maps
//! directly onto "latency must not grow".

use std::sync::Arc;

use simplexmap::coordinator::{Backend, Job, JobQueue, QueueConfig, Scheduler, WorkloadKind};
use simplexmap::util::benchkit::{section, BenchResult, Bencher};
use simplexmap::util::json::Json;
use simplexmap::util::stats::Summary;

const BURST: u64 = 64;

fn job(seed: u64) -> Job {
    Job {
        workload: WorkloadKind::Edm,
        nb: 8,
        map: "lambda2".into(),
        backend: Backend::Serial,
        seed,
    }
}

/// One burst on a fresh queue; returns (p50_secs, p99_secs) of
/// queue_wait from the burst's own metrics.
fn burst() -> (f64, f64) {
    let sched = Arc::new(Scheduler::new(2, None));
    let queue = JobQueue::start(
        Arc::clone(&sched),
        QueueConfig {
            workers: 4,
            capacity: BURST as usize,
        },
    );
    let receivers: Vec<_> = (0..BURST)
        .map(|i| queue.submit(job(i)).expect("burst fits the capacity"))
        .collect();
    for rx in receivers {
        rx.recv()
            .expect("queue alive")
            .expect("small jobs succeed");
    }
    let snap = sched.metrics.snapshot();
    let wait = snap.get("queue_wait").expect("queue_wait phase");
    let q = |key: &str| wait.get(key).and_then(Json::as_f64).unwrap_or(0.0);
    let out = (q("p50_secs"), q("p99_secs"));
    queue.shutdown();
    out
}

fn emit(name: &str, samples: &[f64]) {
    let result = BenchResult {
        name: name.to_string(),
        items_per_iter: 1,
        secs_per_iter: Summary::from_samples(samples).expect("at least one burst"),
    };
    println!("{}", result.report_line());
    if let Ok(path) = std::env::var("SIMPLEXMAP_BENCH_JSON") {
        if !path.is_empty() {
            result.export_json(&path);
        }
    }
}

fn main() {
    section("E19: queue_wait percentiles over full-capacity bursts (64 jobs)");
    let bursts: usize = std::env::var("SIMPLEXMAP_QUEUE_BURSTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(10);
    burst(); // warmup: thread-pool and allocator effects stay out
    let mut p50s = Vec::with_capacity(bursts);
    let mut p99s = Vec::with_capacity(bursts);
    for _ in 0..bursts.max(1) {
        let (p50, p99) = burst();
        p50s.push(p50);
        p99s.push(p99);
    }
    emit("queue_wait_p50", &p50s);
    emit("queue_wait_p99", &p99s);

    section("E19: submit→drain throughput (fresh queue per iteration)");
    let mut b = Bencher::default();
    b.bench("queue_submit_drain_64", BURST, || {
        burst();
    });
}
