//! Multi-step cellular automaton on the embedded Sierpiński gasket,
//! driven by the λ_Δ block-space map: every generation is one
//! 3^k-block launch with zero filler, against a bounding box that
//! would pay (4/3)^k× the parallel space (arXiv:1706.04552's scenario
//! on this repo's engine).
//!
//! Prints a value-sum time series plus (for small n) the live gasket,
//! and the λ_Δ-vs-BB launch accounting.
//!
//! Run: `cargo run --release --example gasket_ca -- [nb] [steps]`

use simplexmap::grid::{BlockShape, LaunchConfig, Launcher};
use simplexmap::maps::{GasketBoundingBoxMap, GasketLambdaMap, MThreadMap};
use simplexmap::simplex::gasket::{gasket_rank, gasket_volume, in_gasket};
use simplexmap::workloads::GasketCAWorkload;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let nb: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(8);
    let steps: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(12);
    let rho = 4u32;

    let mut world = GasketCAWorkload::generate(nb, rho, 2026);
    let map = GasketLambdaMap;
    assert!(map.supports(nb), "nb must be a power of two");
    let mut cfg = LaunchConfig::new(BlockShape::new(rho, 2));
    cfg.launch_latency = std::time::Duration::ZERO;
    let launcher = Launcher::with_workers(
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
        cfg,
    );

    let n = world.n();
    println!(
        "mod-sum CA on the Sierpiński gasket: n={n} ({} of {} grid cells live), \
         map=lambda-gasket, {steps} steps",
        gasket_volume(world.order()),
        n * n
    );
    println!(
        "parallel space: λ_Δ {} blocks vs bb-gasket {} — {:.2}× compaction ((4/3)^k)",
        map.parallel_volume(nb),
        GasketBoundingBoxMap.parallel_volume(nb),
        GasketBoundingBoxMap.parallel_volume(nb) as f64 / map.parallel_volume(nb) as f64
    );

    let per_block = gasket_volume(world.s) as usize;
    let mut series = Vec::new();
    for step in 0..steps {
        series.push(world.sum());
        // One generation = one λ_Δ launch; blocks own disjoint rank
        // slices (mutex only because the kernel is a closure).
        let next = std::sync::Mutex::new(vec![0u8; world.state.len()]);
        let world_ref = &world;
        let stats = launcher.launch(&map, nb, |_lane, b| {
            let base = gasket_rank(world_ref.k, b.data[0], b.data[1]) as usize * per_block;
            let mut tile = vec![0u8; per_block];
            world_ref.tile_next(b.data[0], b.data[1], &mut tile);
            next.lock().unwrap()[base..base + per_block].copy_from_slice(&tile);
            (world_ref.rho as u64).pow(2) - per_block as u64
        });
        assert_eq!(stats.blocks_filler, 0, "λ_Δ wastes nothing");
        world.state = next.into_inner().unwrap();
        if step == 0 {
            println!(
                "  per-step launch: {} blocks ({} threads, {} predicated off), \
                 block efficiency {:.3}",
                stats.blocks_launched,
                stats.threads_launched,
                stats.threads_predicated_off,
                stats.block_efficiency()
            );
        }
    }
    series.push(world.sum());
    println!("value-sum series: {series:?}");

    if n <= 64 {
        println!("final state (rows 0..{n}, '.' = off-gasket):");
        for row in 0..n {
            let mut line = String::new();
            for col in 0..=row {
                if in_gasket(n, col, row) {
                    let v = world.state[gasket_rank(world.order(), col, row) as usize];
                    line.push(char::from_digit(v as u32, 10).unwrap());
                } else {
                    line.push('.');
                }
            }
            println!("  {line}");
        }
    }
}
