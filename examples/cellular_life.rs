//! Multi-step cellular-automaton simulation on a triangular domain
//! (Gardner's Life restricted to the triangle [4]) driven by the λ2
//! map: every generation is one map-driven block sweep, exploiting the
//! bijection for lock-free disjoint writes.
//!
//! Prints a population time series plus (for small n) the live board —
//! the "physical simulation on a triangular spatial domain" scenario
//! §III.A says can simply adopt n = 2^k.
//!
//! Run: `cargo run --release --example cellular_life -- [nb] [steps]`

use simplexmap::grid::{BlockShape, LaunchConfig, Launcher};
use simplexmap::maps::{adapt, Lambda2Map, MThreadMap};
use simplexmap::workloads::CellularWorkload;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let nb: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(8);
    let steps: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(24);
    let rho = 4u32;

    let mut world = CellularWorkload::generate(nb, rho, 2026);
    let map = adapt(Lambda2Map);
    assert!(map.supports(nb), "nb must be a power of two");
    let mut cfg = LaunchConfig::new(BlockShape::new(rho, 2));
    cfg.launch_latency = std::time::Duration::ZERO;
    let launcher = Launcher::with_workers(
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
        cfg,
    );

    let n = world.n;
    println!(
        "Life on a triangular domain: n={n} ({} cells), map=lambda2, {steps} steps",
        n * (n + 1) / 2
    );
    let mut series = Vec::new();
    for step in 0..steps {
        series.push(world.population());
        // One generation = one λ2-mapped launch. Each mapped block
        // computes and scatters its ρ×ρ tile; the bijection guarantees
        // disjoint writes (mutex only because the kernel is a closure).
        let next = std::sync::Mutex::new(vec![0u8; world.state.len()]);
        let world_ref = &world;
        let stats = launcher.launch(&map, nb, |_lane, b| {
            let mut tile = vec![0f32; (rho * rho) as usize];
            world_ref.tile_next(b.data[0], b.data[1], &mut tile);
            world_ref.scatter_tile(b.data[0], b.data[1], &tile, &mut next.lock().unwrap());
            0
        });
        assert_eq!(stats.blocks_filler, 0, "λ2 wastes nothing");
        world.state = next.into_inner().unwrap();
        if step == 0 {
            println!(
                "  per-step launch: {} blocks ({} threads), efficiency {:.3}",
                stats.blocks_launched,
                stats.threads_launched,
                stats.block_efficiency()
            );
        }
    }
    series.push(world.population());

    println!("population: {series:?}");
    if n <= 40 {
        println!("final board:");
        for row in 0..n {
            let mut line = String::from("  ");
            for col in 0..=row {
                line.push(if world.get(row, col) == 1 { '#' } else { '.' });
            }
            println!("{line}");
        }
    }
    // Sanity: the simulation must not explode beyond the domain.
    assert!(series.iter().all(|&p| p <= n * (n + 1) / 2));
    println!("cellular_life OK");
}
