//! Serving demo: start the coordinator's TCP JSON-lines server
//! in-process, act as a client submitting a stream of jobs (mixed
//! workloads and maps), and report latency percentiles — the
//! router-style deployment shape of the L3 coordinator.
//!
//! Run: `cargo run --release --example serve_client -- [jobs]`

use std::io::{BufRead, BufReader, Write};
use std::sync::Arc;

use simplexmap::coordinator::server::Server;
use simplexmap::coordinator::Scheduler;
use simplexmap::util::json;
use simplexmap::util::prng::Xoshiro256;
use simplexmap::util::stats::{fmt_secs, Summary};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let jobs: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(24);

    // Leader in a background thread (rust backend: artifact-free demo).
    let server = Server::new(Arc::new(Scheduler::new(4, None)));
    let (tx, rx) = std::sync::mpsc::channel();
    let handle = std::thread::spawn(move || {
        server
            .serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap())
            .unwrap();
    });
    let addr = rx.recv().unwrap();
    println!("coordinator listening on {addr}");

    // Client: a mixed job stream.
    let mut conn = std::net::TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut rng = Xoshiro256::seed_from_u64(7);
    let workloads = ["edm", "collision", "nbody", "cellular", "trimatvec"];
    let maps = ["lambda2", "bb", "rb", "enum2"];
    let mut latencies = Vec::new();
    let mut line = String::new();
    for i in 0..jobs {
        let w = workloads[rng.gen_range(0, workloads.len())];
        let m = maps[rng.gen_range(0, maps.len())];
        let nb = [16u64, 32, 64][rng.gen_range(0, 3)];
        let req = format!(
            r#"{{"cmd":"run","workload":"{w}","nb":{nb},"map":"{m}","seed":{i}}}"#
        );
        let t0 = std::time::Instant::now();
        conn.write_all(req.as_bytes()).unwrap();
        conn.write_all(b"\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let dt = t0.elapsed().as_secs_f64();
        latencies.push(dt);
        let resp = json::parse(line.trim()).unwrap();
        let ok = resp.get("ok").and_then(|v| v.as_bool()).unwrap_or(false);
        assert!(ok, "job failed: {line}");
        let eff = resp
            .get("result")
            .and_then(|r| r.get("block_efficiency"))
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0);
        println!(
            "  job {i:>3}: {w:<10} nb={nb:<4} map={m:<8} eff={eff:.3} latency={}",
            fmt_secs(dt)
        );
    }

    // Metrics + shutdown.
    conn.write_all(b"{\"cmd\":\"metrics\"}\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let metrics = json::parse(line.trim()).unwrap();
    let completed = metrics
        .get("metrics")
        .and_then(|m| m.get("jobs_completed"))
        .and_then(|v| v.as_u64())
        .unwrap();
    conn.write_all(b"{\"cmd\":\"shutdown\"}\n").unwrap();
    handle.join().unwrap();

    let s = Summary::from_samples(&latencies).unwrap();
    println!(
        "\n{completed} jobs done — latency p50 {} p90 {} p99 {} max {}",
        fmt_secs(s.p50),
        fmt_secs(s.p90),
        fmt_secs(s.p99),
        fmt_secs(s.max)
    );
}
