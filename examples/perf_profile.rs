//! §Perf profiling driver: phase-level breakdown of the pipeline
//! (map phase vs tile-execute phase) per backend, plus batcher
//! occupancy — the numbers the EXPERIMENTS.md §Perf table quotes.
//!
//! Run: `cargo run --release --example perf_profile -- [nb] [reps]`

use simplexmap::coordinator::{Backend, Job, Scheduler, WorkloadKind};
use simplexmap::runtime::{artifact, ExecutorService};
use simplexmap::util::json::Json;

fn phase(snapshot: &Json, key: &str) -> (u64, f64) {
    let p = snapshot.get(key).unwrap();
    (
        p.get("count").unwrap().as_u64().unwrap(),
        p.get("mean_secs").unwrap().as_f64().unwrap(),
    )
}

fn profile(backend: Backend, nb: u64, reps: usize, service: Option<&ExecutorService>) {
    let mut sched = Scheduler::new(
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
        service.map(|s| s.handle()),
    );
    // Phase profiling wants the two-phase split, so opt into the
    // collect flow (the engine's default is the fused streaming mode).
    sched.exec_mode = simplexmap::coordinator::ExecMode::Collect;
    // Warmup.
    let _ = sched.run(&Job {
        workload: WorkloadKind::Edm,
        nb: 8,
        map: "lambda2".into(),
        backend,
        seed: 1,
    });
    let metrics_before = sched.metrics.snapshot();
    let (c0_map, m0_map) = phase(&metrics_before, "map_phase");
    let (c0_ex, m0_ex) = phase(&metrics_before, "exec_phase");

    for i in 0..reps {
        sched
            .run(&Job {
                workload: WorkloadKind::Edm,
                nb,
                map: "lambda2".into(),
                backend,
                seed: i as u64,
            })
            .expect("job");
    }
    let snap = sched.metrics.snapshot();
    let (c_map, mean_map) = phase(&snap, "map_phase");
    let (c_ex, mean_ex) = phase(&snap, "exec_phase");
    // Incremental means over the measured reps.
    let map_secs =
        (mean_map * c_map as f64 - m0_map * c0_map as f64) / (c_map - c0_map) as f64;
    let exec_secs = (mean_ex * c_ex as f64 - m0_ex * c0_ex as f64) / (c_ex - c0_ex) as f64;
    let total = map_secs + exec_secs;
    println!(
        "backend={:<5} nb={nb}: map {:8.3}ms ({:4.1}%)  exec {:8.3}ms ({:4.1}%)  batches={} padded={}",
        backend.name(),
        map_secs * 1e3,
        100.0 * map_secs / total,
        exec_secs * 1e3,
        100.0 * exec_secs / total,
        snap.get("tile_batches").unwrap().as_u64().unwrap(),
        snap.get("tiles_padded").unwrap().as_u64().unwrap(),
    );
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let nb: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(64);
    let reps: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(5);

    println!("EDM pipeline phase breakdown (map=lambda2, {reps} reps):");
    profile(Backend::Rust, nb, reps, None);
    match ExecutorService::spawn_pool(&artifact::default_dir(), 4) {
        Ok(svc) => profile(Backend::Pjrt, nb, reps, Some(&svc)),
        Err(e) => eprintln!("pjrt skipped: {e}"),
    }
}
