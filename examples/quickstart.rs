//! Quickstart: the paper's idea in sixty lines.
//!
//! Builds the λ2 map, shows that its parallel space is exactly half a
//! bounding-box's, verifies the bijection at a small size, and runs
//! one EDM job through the coordinator under both maps.
//!
//! Run: `cargo run --release --example quickstart`

use simplexmap::coordinator::{Backend, Job, Scheduler, WorkloadKind};
use simplexmap::maps::{alpha, space_efficiency, BoundingBox2, Lambda2Map, ThreadMap};

fn main() {
    // --- 1. Parallel-space geometry (the paper's Figs. 2 & 4) -------
    let nb = 256; // blocks per side → n = nb·ρ threads per side
    println!("problem: 2-simplex of {nb} blocks/side");
    println!(
        "  bounding-box: {:>8} blocks launched, efficiency {:.3}, α = {:.3}",
        BoundingBox2.parallel_volume(nb),
        space_efficiency(&BoundingBox2, nb),
        alpha(&BoundingBox2, nb),
    );
    println!(
        "  lambda2:      {:>8} blocks launched, efficiency {:.3}, α = {:.3}",
        Lambda2Map.parallel_volume(nb),
        space_efficiency(&Lambda2Map, nb),
        alpha(&Lambda2Map, nb),
    );

    // --- 2. The O(1) map itself (eq. 13) -----------------------------
    let w = [5u64, 9, 0]; // a block in parallel space
    let d = Lambda2Map.map_block(nb, 0, w).unwrap();
    println!("  λ2({:?}) = {:?}  (col ≤ row < {nb})", &w[..2], &d[..2]);
    assert!(d[0] <= d[1] && d[1] < nb);

    // --- 3. End-to-end: EDM under both maps --------------------------
    let sched = Scheduler::new(4, None);
    for map in ["bb", "lambda2"] {
        let job = Job {
            workload: WorkloadKind::Edm,
            nb: 64,
            map: map.into(),
            backend: Backend::Rust,
            seed: 42,
        };
        let r = sched.run(&job).expect("job");
        println!(
            "  edm map={map:<8} blocks {:>5} launched / {:>5} useful  \
             neighbours={}  wall={:.1}ms",
            r.blocks_launched,
            r.blocks_mapped,
            r.outputs[0].1,
            r.wall_secs * 1e3,
        );
    }
    println!("quickstart OK — same answers, half the parallel space.");
}
