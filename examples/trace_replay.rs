//! Serving-trace replay: generate a Poisson job stream (mixed
//! workloads, maps and sizes) and replay it against the coordinator,
//! reporting end-to-end latency (queueing + service) percentiles —
//! the leader under sustained load.
//!
//! Run: `cargo run --release --example trace_replay -- [jobs] [rate_hz]`

use simplexmap::coordinator::trace::{generate, replay, TraceSpec};
use simplexmap::coordinator::Scheduler;
use simplexmap::util::stats::fmt_secs;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let jobs: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(40);
    let rate_hz: f64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(40.0);

    let sched = Scheduler::new(
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
        None,
    );
    let spec = TraceSpec {
        jobs,
        rate_hz,
        ..Default::default()
    };
    let trace = generate(&spec);
    println!(
        "replaying {jobs} jobs at {rate_hz} jobs/s (trace span {})…",
        fmt_secs(trace.last().unwrap().at.as_secs_f64())
    );
    let report = replay(&sched, &trace);
    println!(
        "completed {} / failed {} in {}",
        report.completed,
        report.failed,
        fmt_secs(report.wall.as_secs_f64())
    );
    if report.latency.count == 0 {
        println!("latency  (no completed jobs)");
    } else {
        println!(
            "latency  p50 {} p90 {} p99 {} p99.9 {} max {}",
            fmt_secs(report.latency.p50),
            fmt_secs(report.latency.p90),
            fmt_secs(report.latency.p99),
            fmt_secs(report.latency.p999),
            fmt_secs(report.latency.max)
        );
        println!(
            "service  p50 {} p90 {} max {}",
            fmt_secs(report.service.p50),
            fmt_secs(report.service.p90),
            fmt_secs(report.service.max)
        );
    }
    let snap = sched.metrics.snapshot();
    println!(
        "jobs_completed={} blocks_mapped={}",
        snap.get("jobs_completed").unwrap(),
        snap.get("blocks_mapped").unwrap()
    );
}
