//! Compare two perf-trajectory JSONL files (the `BenchResult::json_line`
//! format that `SIMPLEXMAP_BENCH_JSON` accumulates) and flag throughput
//! regressions. CI runs this after the bench job to compare the fresh
//! run against the committed BENCH_pr*.json trajectory.
//!
//! Run: `cargo run --release --example bench_compare -- <baseline.jsonl> <current.jsonl> [min_ratio]`
//!
//! Exit status: 0 when every shared benchmark is at or above
//! `min_ratio` (default 0.8 — i.e. tolerate up to 20% noise) of the
//! baseline throughput, 1 when any regressed, 2 on usage/IO errors.

use simplexmap::util::benchkit::compare_trajectories;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let (Some(base_path), Some(cur_path)) = (args.get(1), args.get(2)) else {
        eprintln!("usage: bench_compare <baseline.jsonl> <current.jsonl> [min_ratio]");
        std::process::exit(2);
    };
    let min_ratio: f64 = args.get(3).and_then(|s| s.parse().ok()).unwrap_or(0.8);

    let read = |path: &str| {
        std::fs::read_to_string(path).unwrap_or_else(|e| {
            eprintln!("bench_compare: cannot read {path}: {e}");
            std::process::exit(2);
        })
    };
    let baseline = read(base_path);
    let current = read(cur_path);

    let deltas = compare_trajectories(&baseline, &current);
    if deltas.is_empty() {
        println!("bench_compare: no shared benchmark names between {base_path} and {cur_path}");
        return;
    }

    let mut regressions = 0usize;
    for d in &deltas {
        let flag = if d.regressed(min_ratio) {
            regressions += 1;
            "  << REGRESSION"
        } else {
            ""
        };
        println!("{}{flag}", d.report_line());
    }
    println!(
        "\n{} benchmarks compared, {} regressed (floor {min_ratio}x of baseline throughput)",
        deltas.len(),
        regressions
    );
    if regressions > 0 {
        std::process::exit(1);
    }
}
