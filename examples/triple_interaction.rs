//! 3-simplex end to end: the triple-interaction (Axilrod–Teller)
//! workload of [11]/[6] under BB vs ENUM3 vs λ3 — the paper's §III.C
//! claims on a real O(n³) computation, with the Pallas triple kernel
//! handling all strictly-ordered tiles and Rust predicating the
//! diagonal ones.
//!
//! Run: `cargo run --release --example triple_interaction -- [nb] [backend]`
//! (backend `rust` works without artifacts; `pjrt` needs `make artifacts`)

use simplexmap::coordinator::{Backend, Job, Scheduler, WorkloadKind};
use simplexmap::runtime::{artifact, ExecutorService};
use simplexmap::util::stats::fmt_count;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let nb: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(16);
    let backend = match args.get(2).map(|s| s.as_str()) {
        Some("rust") => Backend::Rust,
        _ => Backend::Pjrt,
    };

    let service = if backend == Backend::Pjrt {
        match ExecutorService::spawn_pool(&artifact::default_dir(), 2) {
            Ok(s) => Some(s),
            Err(e) => {
                eprintln!("artifacts unavailable ({e}); falling back to rust backend");
                None
            }
        }
    } else {
        None
    };
    let backend = if service.is_some() { backend } else { Backend::Rust };
    let sched = Scheduler::new(
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
        service.as_ref().map(|s| s.handle()),
    );

    let n = nb * sched.rho_for(3) as u64;
    let triples = n * (n - 1) * (n - 2) / 6;
    println!(
        "Triple-interaction: {n} particles (nb={nb}, ρ={}), {} unique triples, backend={}",
        sched.rho_for(3),
        fmt_count(triples as f64),
        backend.name()
    );
    println!(
        "{:<12} {:>12} {:>12} {:>8} {:>12} {:>16}",
        "map", "launched", "useful", "eff", "wall", "triples/s"
    );

    let mut energies = Vec::new();
    for map in ["bb", "enum3", "lambda3"] {
        let job = Job {
            workload: WorkloadKind::Triple,
            nb,
            map: map.into(),
            backend,
            seed: 42,
        };
        let r = sched.run(&job).expect("job");
        println!(
            "{:<12} {:>12} {:>12} {:>8.4} {:>10.1}ms {:>16}",
            map,
            r.blocks_launched,
            r.blocks_mapped,
            r.block_efficiency(),
            r.wall_secs * 1e3,
            fmt_count(triples as f64 / r.wall_secs),
        );
        energies.push((map, r.outputs[0].1));
    }

    let e0 = energies[0].1;
    for (map, e) in &energies {
        assert!(
            (e - e0).abs() < 1e-6 * e0.abs().max(1.0),
            "{map}: energy {e} vs {e0}"
        );
    }
    println!(
        "all maps agree: E_AT = {e0:.6e} — λ3 uses ~1/{:.1} of BB's parallel space",
        1.0 + simplexmap::maps::alpha(&simplexmap::maps::BoundingBox3, nb)
    );
}
