//! Chrome trace export: run a small job stream with span recording on
//! and write the resulting lifecycle spans as a Chrome trace-event
//! document — load the file in `chrome://tracing` or Perfetto to see
//! jobs, fused sweeps and (with `SIMPLEXMAP_PROFILE_LANES=1`) per-lane
//! busy intervals nested under them.
//!
//! Run: `cargo run --release --example trace_export -- [out.json] [jobs]`

use simplexmap::coordinator::span;
use simplexmap::coordinator::trace::{generate, replay, TraceSpec};
use simplexmap::coordinator::Scheduler;
use simplexmap::util::json;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let out_path = args
        .get(1)
        .cloned()
        .unwrap_or_else(|| "trace_export.json".to_string());
    let jobs: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(12);

    let recorder = span::global();
    recorder.set_enabled(true);

    let mut sched = Scheduler::new(
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4),
        None,
    );
    // Lane profiling makes the per-lane child spans appear in the
    // trace; it is cheap enough to keep on for an export run.
    sched.profile_lanes = true;

    let spec = TraceSpec {
        jobs,
        rate_hz: 500.0,
        sizes: vec![16, 32],
        ..Default::default()
    };
    let trace = generate(&spec);
    let report = replay(&sched, &trace);
    println!(
        "replayed {} jobs ({} failed); {} spans recorded",
        report.completed,
        report.failed,
        recorder.len()
    );

    let spans = recorder.snapshot_last(recorder.capacity());
    let doc = span::chrome_trace(&spans);
    let text = doc.to_string_compact();
    // The export must survive a round-trip through our own parser —
    // the same guarantee the server's trace command gives clients.
    let back = json::parse(&text).expect("chrome trace round-trips");
    let events = back
        .get("traceEvents")
        .and_then(json::Json::as_arr)
        .expect("traceEvents array");
    assert_eq!(events.len(), spans.len());

    std::fs::write(&out_path, &text).expect("write trace file");
    println!(
        "wrote {} trace events to {out_path} (open in chrome://tracing)",
        events.len()
    );
}
