//! Serving-tier load test: the reactor vs the thread-per-connection
//! baseline, then a thousand-client sweep storm with invariant checks.
//!
//! Four phases, all against in-process servers on the loopback:
//!
//! 1. **Baseline** — the threaded [`Server`], 64 clients each running
//!    jobs one request/response round-trip at a time (the pre-reactor
//!    serving shape).
//! 2. **Reactor @ 64** — same total job count, but each client submits
//!    one `sweep` and reads the streamed frames; reports the aggregate
//!    throughput ratio over phase 1.
//! 3. **Scale** — `SIMPLEXMAP_LOAD_CLIENTS` (default 1000) concurrent
//!    sweep clients. Every client verifies its own frame stream (each
//!    row exactly once, done-frame counts consistent) while a sampler
//!    polls `{"cmd":"metrics"}` and records the peak queue depth.
//! 4. **Reconnect** — `SIMPLEXMAP_LOAD_RECONNECT_CLIENTS` (default 64)
//!    clients each start a non-streaming sweep, hard-drop the
//!    connection right after the ack, then recover every row by the
//!    durable token from a fresh connection (0 disables the phase).
//!
//! Exit is nonzero if any result is lost or duplicated (including
//! across the phase-4 disconnects), the queue depth ever exceeds its
//! capacity, or the throughput ratio falls under
//! `SIMPLEXMAP_LOAD_MIN_RATIO` (default 0 = report only).
//!
//! Run: `cargo run --release --example load_test`
//! Knobs: `SIMPLEXMAP_LOAD_CLIENTS`, `SIMPLEXMAP_LOAD_JOBS` (rows per
//! scale-phase sweep), `SIMPLEXMAP_LOAD_BASE_JOBS` (jobs per phase-1/2
//! client), `SIMPLEXMAP_LOAD_WINDOW`, `SIMPLEXMAP_LOAD_MIN_RATIO`,
//! `SIMPLEXMAP_LOAD_RECONNECT_CLIENTS`.
//!
//! Memory-ordering policy: the shared tallies are summed after every
//! client thread is joined (the join is the synchronization edge), so
//! the counters themselves are Relaxed.
// lint: atomics(Relaxed)

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use simplexmap::coordinator::server::Server;
use simplexmap::coordinator::{QueueConfig, Reactor, ReactorConfig, Scheduler};
use simplexmap::util::json::{self, Json};

const QUEUE_CAPACITY: usize = 64;

fn env_u64(key: &str, default: u64) -> u64 {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Lift the open-file ceiling to its hard limit so a thousand client
/// sockets (plus the server side of each) fit in one process.
#[cfg(target_os = "linux")]
fn raise_nofile() {
    #[repr(C)]
    struct RLimit {
        cur: u64,
        max: u64,
    }
    extern "C" {
        fn getrlimit(resource: i32, rlim: *mut RLimit) -> i32;
        fn setrlimit(resource: i32, rlim: *const RLimit) -> i32;
    }
    const RLIMIT_NOFILE: i32 = 7;
    // SAFETY: `r` is a live, properly aligned `#[repr(C)]` mirror of
    // the kernel's `struct rlimit`; getrlimit/setrlimit only read or
    // write through the pointer for the duration of the call.
    unsafe {
        let mut r = RLimit { cur: 0, max: 0 };
        if getrlimit(RLIMIT_NOFILE, &mut r) == 0 && r.cur < r.max {
            r.cur = r.max;
            let _ = setrlimit(RLIMIT_NOFILE, &r);
        }
    }
}
#[cfg(not(target_os = "linux"))]
fn raise_nofile() {}

fn queue_config() -> QueueConfig {
    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(8);
    QueueConfig {
        workers,
        capacity: QUEUE_CAPACITY,
    }
}

fn spawn_threaded() -> (SocketAddr, std::thread::JoinHandle<()>) {
    let server = Server::with_queue(Arc::new(Scheduler::new(2, None)), queue_config());
    let (tx, rx) = std::sync::mpsc::channel();
    let handle = std::thread::spawn(move || {
        server
            .serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap())
            .unwrap();
    });
    (rx.recv().unwrap(), handle)
}

fn spawn_reactor() -> (SocketAddr, std::thread::JoinHandle<()>) {
    let cfg = ReactorConfig {
        queue: queue_config(),
        ..ReactorConfig::from_env()
    };
    let reactor = Reactor::with_config(Arc::new(Scheduler::new(2, None)), cfg);
    let (tx, rx) = std::sync::mpsc::channel();
    let handle = std::thread::spawn(move || {
        reactor
            .serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap())
            .unwrap();
    });
    (rx.recv().unwrap(), handle)
}

fn connect(addr: SocketAddr) -> std::io::Result<(TcpStream, BufReader<TcpStream>)> {
    let stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true)?;
    let reader = BufReader::new(stream.try_clone()?);
    Ok((stream, reader))
}

fn read_json(reader: &mut BufReader<TcpStream>, what: &str) -> Result<Json, String> {
    let mut line = String::new();
    let n = reader
        .read_line(&mut line)
        .map_err(|e| format!("read {what}: {e}"))?;
    if n == 0 {
        return Err(format!("connection closed awaiting {what}"));
    }
    json::parse(line.trim()).map_err(|e| format!("bad {what}: {e}"))
}

/// Phase-1 client: `jobs` sequential run round-trips; returns ok count.
fn baseline_client(addr: SocketAddr, seed: u64, jobs: u64) -> Result<u64, String> {
    let (mut w, mut r) = connect(addr).map_err(|e| e.to_string())?;
    let mut ok = 0u64;
    for i in 0..jobs {
        let req = format!(
            "{{\"cmd\":\"run\",\"workload\":\"edm\",\"nb\":8,\"map\":\"lambda2\",\
             \"backend\":\"serial\",\"seed\":{}}}\n",
            seed * 10_000 + i
        );
        w.write_all(req.as_bytes()).map_err(|e| e.to_string())?;
        let reply = read_json(&mut r, "run reply")?;
        if reply.get("ok").and_then(Json::as_bool) == Some(true) {
            ok += 1;
        } else {
            return Err(format!("run refused: {}", reply.to_string_compact()));
        }
    }
    Ok(ok)
}

/// Sweep client: one streamed sweep of `jobs` rows, each row verified
/// to arrive exactly once; returns (completed, failed) from the done
/// frame after cross-checking against the frames actually seen.
fn sweep_client(addr: SocketAddr, seed: u64, jobs: u64, window: u64) -> Result<(u64, u64), String> {
    let (mut w, mut r) = connect(addr).map_err(|e| e.to_string())?;
    let nbs: Vec<String> = (0..jobs).map(|_| "8".to_string()).collect();
    let req = format!(
        "{{\"cmd\":\"sweep\",\"workloads\":[\"edm\"],\"maps\":[\"lambda2\"],\"nbs\":[{}],\
         \"backend\":\"serial\",\"seed\":{seed},\"window\":{window}}}\n",
        nbs.join(",")
    );
    w.write_all(req.as_bytes()).map_err(|e| e.to_string())?;
    let ack = read_json(&mut r, "sweep ack")?;
    if ack.get("ok").and_then(Json::as_bool) != Some(true) {
        return Err(format!("sweep refused: {}", ack.to_string_compact()));
    }
    let total = ack.get("jobs").and_then(Json::as_u64).unwrap_or(0);
    if total != jobs {
        return Err(format!("ack says {total} jobs, expected {jobs}"));
    }
    let mut seen = vec![false; jobs as usize];
    let mut frames = 0u64;
    loop {
        let frame = read_json(&mut r, "sweep frame")?;
        if frame.get("done").and_then(Json::as_bool) == Some(true) {
            let completed = frame.get("completed").and_then(Json::as_u64).unwrap_or(0);
            let failed = frame.get("failed").and_then(Json::as_u64).unwrap_or(0);
            if frames != jobs || seen.iter().any(|s| !s) || completed + failed != jobs {
                return Err(format!(
                    "lost/duplicated rows: saw {frames}/{jobs} frames, \
                     done counts {completed}+{failed}"
                ));
            }
            return Ok((completed, failed));
        }
        let idx = frame
            .get("job")
            .and_then(Json::as_u64)
            .ok_or_else(|| format!("frame without job index: {}", frame.to_string_compact()))?;
        let slot = seen
            .get_mut(idx as usize)
            .ok_or(format!("job index {idx} out of range"))?;
        if *slot {
            return Err(format!("duplicate frame for job {idx}"));
        }
        *slot = true;
        frames += 1;
    }
}

/// Phase-4 client: start a non-streaming sweep, hard-drop the
/// connection straight after the ack (mid-fan-out for any realistic
/// row count), then reconnect and page every row back by the durable
/// token — the results-outlive-the-connection contract under load.
fn reconnect_client(addr: SocketAddr, seed: u64, jobs: u64, window: u64) -> Result<(), String> {
    let token = {
        let (mut w, mut r) = connect(addr).map_err(|e| e.to_string())?;
        let nbs: Vec<String> = (0..jobs).map(|_| "8".to_string()).collect();
        let req = format!(
            "{{\"cmd\":\"sweep\",\"workloads\":[\"edm\"],\"maps\":[\"lambda2\"],\"nbs\":[{}],\
             \"backend\":\"serial\",\"seed\":{seed},\"window\":{window},\"stream\":false}}\n",
            nbs.join(",")
        );
        w.write_all(req.as_bytes()).map_err(|e| e.to_string())?;
        let ack = read_json(&mut r, "sweep ack")?;
        if ack.get("ok").and_then(Json::as_bool) != Some(true) {
            return Err(format!("sweep refused: {}", ack.to_string_compact()));
        }
        ack.get("token")
            .and_then(Json::as_str)
            .ok_or("ack has no token")?
            .to_string()
        // Both socket halves drop here: the hard disconnect.
    };
    let (mut w, mut r) = connect(addr).map_err(|e| e.to_string())?;
    let deadline = Instant::now() + Duration::from_secs(120);
    let mut cursor = 0u64;
    loop {
        let req = format!(
            "{{\"cmd\":\"results\",\"token\":\"{token}\",\"cursor\":{cursor},\"limit\":64}}\n"
        );
        w.write_all(req.as_bytes()).map_err(|e| e.to_string())?;
        let page = read_json(&mut r, "results page")?;
        if page.get("ok").and_then(Json::as_bool) != Some(true) {
            return Err(format!("results refused: {}", page.to_string_compact()));
        }
        let total = page.get("jobs").and_then(Json::as_u64).unwrap_or(0);
        if total != jobs {
            return Err(format!("token pages {total} jobs, expected {jobs}"));
        }
        let rows = page.get("results").and_then(Json::as_arr).unwrap_or(&[]);
        let mut advanced = false;
        for row in rows {
            if matches!(row, Json::Null) {
                break;
            }
            if row.get("job").and_then(Json::as_u64) != Some(cursor) {
                return Err(format!(
                    "cursor {cursor} got wrong row: {}",
                    row.to_string_compact()
                ));
            }
            cursor += 1;
            advanced = true;
        }
        if cursor >= total {
            return Ok(());
        }
        if !advanced {
            if Instant::now() > deadline {
                return Err(format!("timed out at cursor {cursor}/{total}"));
            }
            std::thread::sleep(Duration::from_millis(10));
        }
    }
}

/// Run `clients` threads of `work` and return (errors, elapsed).
fn run_clients<F>(clients: u64, stagger: bool, work: F) -> (Vec<String>, Duration)
where
    F: Fn(u64) -> Result<(), String> + Send + Sync + 'static,
{
    let work = Arc::new(work);
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for id in 0..clients {
        let work = Arc::clone(&work);
        let builder = std::thread::Builder::new().stack_size(192 * 1024);
        handles.push(
            builder
                .spawn(move || {
                    if stagger {
                        // Spread connects so the listener backlog never
                        // sees a thousand simultaneous SYNs.
                        std::thread::sleep(Duration::from_millis(id % 97));
                    }
                    work(id).err()
                })
                .expect("spawn client thread"),
        );
    }
    let mut errors = Vec::new();
    for h in handles {
        if let Some(e) = h.join().expect("client thread panicked") {
            errors.push(e);
        }
    }
    (errors, t0.elapsed())
}

/// Poll the server's metrics until `stop`, tracking peak queue depth.
fn depth_sampler(addr: SocketAddr, stop: Arc<AtomicBool>, peak: Arc<AtomicU64>) {
    let Ok((mut w, mut r)) = connect(addr) else {
        return;
    };
    while !stop.load(Ordering::Relaxed) {
        if w.write_all(b"{\"cmd\":\"metrics\"}\n").is_err() {
            return;
        }
        let Ok(reply) = read_json(&mut r, "metrics") else {
            return;
        };
        let depth = reply
            .get("metrics")
            .and_then(|m| m.get("queue_depth"))
            .and_then(Json::as_u64)
            .unwrap_or(0);
        peak.fetch_max(depth, Ordering::Relaxed);
        std::thread::sleep(Duration::from_millis(5));
    }
}

fn shutdown(addr: SocketAddr, handle: std::thread::JoinHandle<()>) {
    if let Ok((mut w, mut r)) = connect(addr) {
        let _ = w.write_all(b"{\"cmd\":\"shutdown\"}\n");
        let _ = read_json(&mut r, "shutdown ack");
    }
    handle.join().expect("server thread panicked");
}

fn main() {
    raise_nofile();
    let base_clients = 64u64;
    let base_jobs = env_u64("SIMPLEXMAP_LOAD_BASE_JOBS", 10);
    let scale_clients = env_u64("SIMPLEXMAP_LOAD_CLIENTS", 1000);
    let scale_jobs = env_u64("SIMPLEXMAP_LOAD_JOBS", 8);
    let window = env_u64("SIMPLEXMAP_LOAD_WINDOW", 16);
    let min_ratio = env_f64("SIMPLEXMAP_LOAD_MIN_RATIO", 0.0);
    let mut failed = false;

    // Phase 1: threaded baseline, one round-trip per job.
    let (addr, handle) = spawn_threaded();
    let (errors, elapsed) = run_clients(base_clients, false, move |id| {
        baseline_client(addr, id, base_jobs).map(|_| ())
    });
    shutdown(addr, handle);
    let base_total = base_clients * base_jobs;
    let base_tput = base_total as f64 / elapsed.as_secs_f64();
    println!(
        "phase 1 threaded : {base_clients} clients x {base_jobs} jobs -> \
         {base_tput:>8.0} jobs/s ({} errors)",
        errors.len()
    );
    failed |= !errors.is_empty();

    // Phase 2: reactor, same totals, one streamed sweep per client.
    let (addr, handle) = spawn_reactor();
    let (errors, elapsed) = run_clients(base_clients, false, move |id| {
        sweep_client(addr, id, base_jobs, window).map(|_| ())
    });
    shutdown(addr, handle);
    let reactor_tput = base_total as f64 / elapsed.as_secs_f64();
    let ratio = reactor_tput / base_tput;
    println!(
        "phase 2 reactor  : {base_clients} clients x {base_jobs} rows -> \
         {reactor_tput:>8.0} jobs/s ({} errors) — {ratio:.2}x over threaded",
        errors.len()
    );
    failed |= !errors.is_empty();
    if min_ratio > 0.0 && ratio < min_ratio {
        println!("FAIL: throughput ratio {ratio:.2} under the {min_ratio:.2} floor");
        failed = true;
    }

    // Phase 3: the sweep storm with invariant checks.
    let (addr, handle) = spawn_reactor();
    let stop = Arc::new(AtomicBool::new(false));
    let peak = Arc::new(AtomicU64::new(0));
    let sampler = {
        let (stop, peak) = (Arc::clone(&stop), Arc::clone(&peak));
        std::thread::spawn(move || depth_sampler(addr, stop, peak))
    };
    let completed = Arc::new(AtomicU64::new(0));
    let sum = Arc::clone(&completed);
    let (errors, elapsed) = run_clients(scale_clients, true, move |id| {
        let (done, fail) = sweep_client(addr, id, scale_jobs, window)?;
        sum.fetch_add(done + fail, Ordering::Relaxed);
        Ok(())
    });
    stop.store(true, Ordering::Relaxed);
    sampler.join().expect("sampler panicked");
    shutdown(addr, handle);
    let scale_total = scale_clients * scale_jobs;
    let got = completed.load(Ordering::Relaxed);
    let depth = peak.load(Ordering::Relaxed);
    println!(
        "phase 3 scale    : {scale_clients} clients x {scale_jobs} rows -> \
         {got}/{scale_total} results in {:.2}s, peak queue depth {depth}/{QUEUE_CAPACITY} \
         ({} errors)",
        elapsed.as_secs_f64(),
        errors.len()
    );
    for e in errors.iter().take(5) {
        println!("  client error: {e}");
    }
    if !errors.is_empty() || got != scale_total {
        println!("FAIL: lost or duplicated results under load");
        failed = true;
    }
    if depth as usize > QUEUE_CAPACITY {
        println!("FAIL: queue depth {depth} exceeded capacity {QUEUE_CAPACITY}");
        failed = true;
    }

    // Phase 4: kill-and-reconnect — every client hard-drops its
    // connection right after the sweep ack and recovers all rows by
    // token from a fresh connection.
    let reconnect_clients = env_u64("SIMPLEXMAP_LOAD_RECONNECT_CLIENTS", 64);
    if reconnect_clients > 0 {
        let (addr, handle) = spawn_reactor();
        let (errors, elapsed) = run_clients(reconnect_clients, true, move |id| {
            reconnect_client(addr, id, scale_jobs, window)
        });
        shutdown(addr, handle);
        println!(
            "phase 4 reconnect: {reconnect_clients} clients x {scale_jobs} rows, \
             conn dropped post-ack -> all rows recovered by token in {:.2}s ({} errors)",
            elapsed.as_secs_f64(),
            errors.len()
        );
        for e in errors.iter().take(5) {
            println!("  client error: {e}");
        }
        if !errors.is_empty() {
            println!("FAIL: results lost across reconnect");
            failed = true;
        }
    }

    if failed {
        std::process::exit(1);
    }
    println!("load test OK: zero lost results, queue depth bounded, reconnect durable");
}
