//! E10 headline — the full three-layer stack on a real workload.
//!
//! Runs the Euclidean-distance-matrix workload end to end:
//! Rust coordinator → thread map (BB vs ENUM2 vs λ2) → tile batcher →
//! **AOT-compiled Pallas kernels via PJRT** → aggregation; prints
//! per-map throughput (useful pair-distances per second), parallel-
//! space efficiency and the cross-backend checksum agreement.
//!
//! Requires `make artifacts`. Results recorded in EXPERIMENTS.md §E10.
//!
//! Run: `cargo run --release --example edm_end_to_end -- [nb] [seed]`

use simplexmap::coordinator::{Backend, Job, Scheduler, WorkloadKind};
use simplexmap::runtime::{artifact, ExecutorService};
use simplexmap::util::stats::fmt_count;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let nb: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(64);
    let seed: u64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(42);

    let dir = artifact::default_dir();
    let service = match ExecutorService::spawn_pool(&dir, 2) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("cannot load artifacts ({e}); run `make artifacts` first");
            std::process::exit(1);
        }
    };
    let sched = Scheduler::new(
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
        Some(service.handle()),
    );
    let n_points = nb * sched.rho_for(2) as u64;
    let pairs = n_points * (n_points - 1) / 2;
    println!(
        "EDM end-to-end: {n_points} points (nb={nb}, ρ={}), {} unique pairs, backend=pjrt (Pallas tiles)",
        sched.rho_for(2),
        fmt_count(pairs as f64)
    );
    println!(
        "{:<10} {:>10} {:>10} {:>8} {:>12} {:>12} {:>14}",
        "map", "launched", "useful", "eff", "wall", "batches", "pairs/s"
    );

    // Warm the executor (first PJRT execution pays one-time costs).
    let _ = sched.run(&Job {
        workload: WorkloadKind::Edm,
        nb: nb.min(8),
        map: "bb".into(),
        backend: Backend::Pjrt,
        seed,
    });

    let mut checksums = Vec::new();
    for map in ["bb", "enum2", "lambda2", "rb"] {
        let job = Job {
            workload: WorkloadKind::Edm,
            nb,
            map: map.into(),
            backend: Backend::Pjrt,
            seed,
        };
        let r = sched.run(&job).expect("job");
        println!(
            "{:<10} {:>10} {:>10} {:>8.4} {:>10.1}ms {:>12} {:>14}",
            map,
            r.blocks_launched,
            r.blocks_mapped,
            r.block_efficiency(),
            r.wall_secs * 1e3,
            r.tile_batches,
            fmt_count(pairs as f64 / r.wall_secs),
        );
        checksums.push((map, r.outputs[0].1, r.outputs[1].1));
    }

    // All maps must compute identical answers.
    let (c0, s0) = (checksums[0].1, checksums[0].2);
    for (map, c, s) in &checksums {
        assert_eq!(*c, c0, "{map} neighbour count differs");
        assert!((s - s0).abs() < 1e-6 * s0.abs(), "{map} Σd² differs");
    }
    println!(
        "all maps agree: neighbours={c0}, Σd²={s0:.3e} — λ2 delivers the same answer \
         with {:.1}% of BB's parallel space",
        100.0 / (1.0 + simplexmap::maps::alpha(&simplexmap::maps::BoundingBox2, nb))
    );
}
