//! §III.D interactive study (E9): sweep the (m, β) parameter space of
//! the general recursive set, print the n₀/waste Pareto frontier per
//! dimension, and quantify the "m!× more efficient than bounding-box"
//! claim.
//!
//! Run: `cargo run --release --example param_search -- [m_max]`

use simplexmap::gensearch::{pareto, search};
use simplexmap::simplex::volume::factorial;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let m_max: u32 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(9);
    let betas: Vec<f64> = vec![2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0];
    let horizon = 1u64 << 40;

    let rows = search((4, m_max), &betas, horizon);
    for m in 4..=m_max {
        println!("m = {m} (m! = {}, BB wastes {}×):", factorial(m), factorial(m) - 1);
        println!(
            "  {:>8} {:>12} {:>12} {:>14}  pareto",
            "beta", "n0", "waste lim", "eff vs BB"
        );
        let front = pareto(&rows, m);
        for r in rows.iter().filter(|r| r.m == m) {
            let on_front = front
                .iter()
                .any(|f| f.beta == r.beta && f.n0 == r.n0);
            println!(
                "  {:>8} {:>12} {:>12.4} {:>14.1}  {}",
                r.beta,
                r.n0.map(|v| v.to_string()).unwrap_or_else(|| "> horizon".into()),
                r.waste_limit,
                r.efficiency_vs_bb,
                if on_front { "*" } else { "" }
            );
        }
        println!();
    }
    println!(
        "reading: raising β pulls n₀ toward the origin but pays waste β/(m!-β);\n\
         every starred row is Pareto-optimal — the open optimization problem of §III.D."
    );
}
