//! General-m end to end: the unique k-tuple interaction workload under
//! BB_m vs λ_m — §III.D's ≈m! parallel-space claim on a real O(n^m)
//! computation, through the same scheduler every other workload uses.
//!
//! Run: `cargo run --release --example ktuple_interaction -- [m] [nb]`
//! (defaults m=4, nb=28 — λ_m's first covered size, where it uses
//! ~1/19.5 of BB's parallel space; small nb also brute-force checks).

use simplexmap::coordinator::{Backend, Job, Scheduler, WorkloadKind};
use simplexmap::maps::map_names;
use simplexmap::simplex::volume::binomial;
use simplexmap::util::stats::fmt_count;
use simplexmap::workloads::KTupleWorkload;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let m: u32 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let nb: u64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(28);
    let workload = WorkloadKind::ktuple(m).expect("arity within 3..=8");

    let sched = Scheduler::new(
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4),
        None,
    );
    let rho = sched.rho_for(m);
    let n = nb * rho as u64;
    let tuples = binomial(n as u128, m as u128);
    println!(
        "k-tuple interaction: {n} particles (nb={nb}, ρ={rho}), m={m}, {} unique tuples",
        fmt_count(tuples as f64)
    );
    println!(
        "{:<14} {:>12} {:>12} {:>8} {:>12} {:>16}",
        "map", "launched", "useful", "eff", "wall", "tuples/s"
    );

    let mut energies = Vec::new();
    for map in map_names(m) {
        let job = Job {
            workload,
            nb,
            map: map.clone(),
            backend: Backend::Rust,
            seed: 42,
        };
        let r = sched.run(&job).expect("job");
        println!(
            "{:<14} {:>12} {:>12} {:>8.4} {:>10.1}ms {:>16}",
            map,
            r.blocks_launched,
            r.blocks_mapped,
            r.block_efficiency(),
            r.wall_secs * 1e3,
            fmt_count(tuples as f64 / r.wall_secs),
        );
        energies.push((map, r.outputs[0].1));
    }

    let e0 = energies[0].1;
    for (map, e) in &energies {
        assert!(
            (e - e0).abs() < 1e-9 * e0.abs().max(1.0),
            "{map}: energy {e} vs {e0}"
        );
    }
    println!("all maps agree: E = {e0:.6e}");

    if n <= 16 {
        let w = KTupleWorkload::generate(nb, rho, m, 42);
        let want = w.reference();
        assert!(
            (want - e0).abs() < 1e-9 * want.abs().max(1.0),
            "reference {want} vs {e0}"
        );
        println!("brute-force reference agrees: {want:.6e}");
    }
}
