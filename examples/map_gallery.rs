//! Map gallery — the executable version of the paper's Figures 4, 6
//! and 7: render where every registered map sends each parallel block,
//! labelled by recursion level, so the recursive decompositions are
//! visible side by side.
//!
//! Run: `cargo run --release --example map_gallery -- [nb2] [nb3]`

use simplexmap::analysis::viz::{render_m2, render_m3};
use simplexmap::maps::{map2_by_name, map3_by_name, MAP2_NAMES, MAP3_NAMES};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let nb2: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(16);
    let nb3: u64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(8);

    println!("== 2-simplex maps at nb = {nb2} (Fig. 4) ==");
    for name in MAP2_NAMES {
        let map = map2_by_name(name).expect("registered map");
        if !map.supports(nb2) {
            println!("\n-- {name}: does not support nb={nb2}, skipped --");
            continue;
        }
        println!("\n-- {name} (passes = {}) --", map.passes(nb2));
        let rendered = render_m2(map.as_ref(), nb2);
        print!("{rendered}");
        // Bijective maps must leave no hole; BB-style maps may.
        if !rendered.contains('.') {
            println!("   (exact cover: no holes)");
        }
    }

    println!("\n== 3-simplex maps at nb = {nb3} (Figs. 6-7) ==");
    for name in MAP3_NAMES {
        let map = map3_by_name(name).expect("registered map");
        if !map.supports(nb3) {
            println!("\n-- {name}: does not support nb={nb3}, skipped --");
            continue;
        }
        println!("\n-- {name} (passes = {}) --", map.passes(nb3));
        print!("{}", render_m3(map.as_ref(), nb3));
    }
    println!("\nmap_gallery OK");
}
