//! Collision-culling scenario — the workload of Avril et al. [1] that
//! motivates thread maps in the first place, plus the E11 accuracy
//! study: the f32 thread-space map's error cliff vs λ2's exact integer
//! arithmetic.
//!
//! Run: `cargo run --release --example collision_detection -- [nb]`

use simplexmap::coordinator::{Backend, Job, Scheduler, WorkloadKind};
use simplexmap::maps::avril::f32_error_rate;
use simplexmap::util::stats::fmt_count;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let nb: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(64);

    let sched = Scheduler::new(
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
        None,
    );
    let n = nb * sched.rho_for(2) as u64;
    println!("Broad-phase AABB culling over {n} boxes:");
    println!(
        "{:<10} {:>10} {:>10} {:>8} {:>12} {:>14}",
        "map", "launched", "useful", "eff", "wall", "pairs/s"
    );
    let pairs = n * (n - 1) / 2;
    let mut counts = Vec::new();
    for map in ["bb", "enum2", "lambda2", "rb", "ries"] {
        let job = Job {
            workload: WorkloadKind::Collision,
            nb,
            map: map.into(),
            backend: Backend::Rust,
            seed: 42,
        };
        let r = sched.run(&job).expect("job");
        println!(
            "{:<10} {:>10} {:>10} {:>8.4} {:>10.1}ms {:>14}",
            map,
            r.blocks_launched,
            r.blocks_mapped,
            r.block_efficiency(),
            r.wall_secs * 1e3,
            fmt_count(pairs as f64 / r.wall_secs),
        );
        counts.push((map, r.outputs[0].1 as u64));
    }
    let c0 = counts[0].1;
    for (map, c) in &counts {
        assert_eq!(*c, c0, "{map}");
    }
    println!("all maps find the same {c0} colliding pairs\n");

    // E11: why thread-space f32 maps stop being an option at scale.
    println!("E11: f32 thread-space map (Avril) error rate vs problem size:");
    for n in [1000u64, 2000, 3000, 5000, 10_000, 30_000] {
        let stride = (n * (n - 1) / 2 / 20_000).max(1);
        let rate = f32_error_rate(n, stride);
        println!(
            "  n={n:>6}: {:.4}%  {}",
            rate * 100.0,
            if rate == 0.0 { "(exact)" } else { "(BROKEN — λ2 stays exact)" }
        );
    }
}
